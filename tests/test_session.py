"""Sessions model (MPI 4.0 §11): session lifecycle, process-set discovery,
the full group algebra, and ``Communicator.from_group`` as the canonical
constructor (``world()`` is a shim over it)."""

from __future__ import annotations

import textwrap

import jax
import pytest

from repro.core import errors
from repro.core.communicator import Communicator, world
from repro.core.session import (
    UNDEFINED,
    Group,
    GroupComparison,
    Session,
    default_session,
)


# ---------------------------------------------------------------------------
# group algebra (Groups are device-agnostic: any hashable members work)
# ---------------------------------------------------------------------------


def test_group_union_order():
    a, b = Group("abc"), Group("cbd")
    assert Group("abc").union(Group("cbd")).devices == tuple("abcd")
    assert (a | b).devices == tuple("abcd")
    assert (b | a).devices == tuple("cbda")


def test_group_intersection_ordered_by_self():
    a, b = Group("abcd"), Group("dca")
    assert a.intersection(b).devices == tuple("acd")
    assert (b & a).devices == tuple("dca")


def test_group_difference():
    a, b = Group("abcd"), Group("bd")
    assert a.difference(b).devices == tuple("ac")
    assert (b - a).size() == 0


def test_group_incl_excl():
    g = Group("abcd")
    assert g.incl([2, 0]).devices == ("c", "a")
    assert g.excl([1, 3]).devices == ("a", "c")
    with pytest.raises(errors.RankError):
        g.incl([0, 0])
    with pytest.raises(errors.RankError):
        g.incl([4])
    with pytest.raises(errors.RankError):
        g.excl([-1])


def test_group_rank_size_translate():
    g = Group("abcd")
    assert g.size() == len(g) == 4
    assert g.rank("c") == 2 and g.rank("z") == UNDEFINED
    assert g.device(1) == "b"
    sub = g.incl([3, 1])
    assert sub.translate_ranks([0, 1], g) == [3, 1]
    assert g.translate_ranks([0, 3], sub) == [UNDEFINED, 0]


def test_group_compare():
    g = Group("abc")
    assert g.compare(Group("abc")) is GroupComparison.IDENT
    assert g.compare(Group("cba")) is GroupComparison.SIMILAR
    assert g.compare(Group("ab")) is GroupComparison.UNEQUAL
    assert g == Group("abc") and g != Group("cba")
    assert hash(g) == hash(Group("abc"))


def test_group_dedups_preserving_order():
    assert Group("abab").devices == ("a", "b")


# ---------------------------------------------------------------------------
# session lifecycle + process-set discovery
# ---------------------------------------------------------------------------


def test_session_discovers_builtin_psets():
    sess = Session.init()
    names = sess.psets()
    assert "repro://world" in names and "repro://self" in names
    assert sess.num_psets() == len(names)
    assert any(n.startswith("repro://host/") for n in names)
    assert any(n.startswith("repro://platform/") for n in names)
    n = len(jax.devices())
    assert sess.group("repro://world").size() == n
    assert sess.pset_info("repro://world")["mpi_size"] == n
    # mpi:// spellings alias the repro:// namespace, case-insensitively
    assert sess.group("mpi://WORLD").size() == n


def test_session_finalize_lifecycle():
    sess = Session.init()
    sess.finalize()
    assert sess.finalized
    with pytest.raises(errors.SessionError):
        sess.group("repro://world")
    with pytest.raises(errors.SessionError):
        sess.psets()
    # context manager finalizes on exit
    with Session.init() as s2:
        assert s2.group().size() >= 1
    assert s2.finalized


def test_session_register_pset():
    sess = Session.init()
    g = sess.group("repro://world")
    name = sess.register_pset("repro://mine", g.incl([0]))
    assert name == "repro://mine"
    assert sess.group("repro://mine").size() == 1
    with pytest.raises(errors.ArgError):
        sess.register_pset("repro://world", g)  # builtins are not shadowable
    with pytest.raises(errors.GroupError):
        sess.register_pset("repro://empty", Group())
    with pytest.raises(errors.GroupError):
        sess.register_pset("repro://alien", ["not-a-device"])
    with pytest.raises(errors.ArgError):
        sess.group("repro://nonexistent")


def test_default_session_caching():
    a, b = default_session(), default_session()
    assert a is b
    # refresh re-enumerates in place, preserving user-registered psets
    a.register_pset("repro://sticky", a.group().incl([0]))
    assert default_session(refresh=True) is a
    assert a.group("repro://sticky").size() == 1
    assert a.group("repro://world").size() == len(jax.devices())
    # a finalized default is replaced automatically
    default_session().finalize()
    assert not default_session().finalized


class _FakeDev:
    """Stands in for a device that appears/disappears between refreshes."""

    def __init__(self, i: int):
        self.id = 1000 + i
        self.process_index = 0
        self.platform = "elastic"

    def __repr__(self):
        return f"FakeDev({self.id})"


def test_refresh_rederives_world_when_devices_appear():
    sess = Session.init()
    real = sess.pset("repro://world")
    joined = tuple(real) + (_FakeDev(0), _FakeDev(1))
    sess.refresh(devices=joined)
    assert sess.group("repro://world").size() == len(real) + 2
    assert sess.group("repro://platform/elastic").size() == 2
    # back to reality: the builtin sets re-derive, the fakes are gone
    sess.refresh()
    assert sess.group("repro://world").size() == len(real)
    assert "repro://platform/elastic" not in sess.psets()


def test_refresh_prunes_vanished_devices_from_user_psets():
    sess = Session.init()
    real = sess.pset("repro://world")
    fakes = (_FakeDev(0), _FakeDev(1))
    sess.refresh(devices=tuple(real) + fakes)
    sess.register_pset("repro://doomed", Group(fakes))
    sess.register_pset("repro://mixed", Group([real[0], fakes[0]]))
    sess.register_pset("repro://stable", Group([real[0]]))

    sess.refresh(devices=tuple(real))  # the fake devices disappear
    # a pset whose members all vanished is dropped; survivors are pruned —
    # no user pset may keep naming hardware the platform no longer has
    assert "repro://doomed" not in sess.psets()
    assert sess.pset("repro://mixed") == (real[0],)
    assert sess.pset("repro://stable") == (real[0],)
    with pytest.raises(errors.ArgError):
        sess.group("repro://doomed")


def test_from_group_shape_axis_mismatch():
    g = default_session().group("repro://world")
    with pytest.raises(errors.DimsError):
        Communicator.from_group(g, shape=(1, g.size()), axis_names=("only_one",))


# ---------------------------------------------------------------------------
# Communicator.from_group + the world() shim
# ---------------------------------------------------------------------------


def test_world_is_a_session_shim():
    comm = world(refresh=True)
    assert comm.axis_names == ("world",)
    assert comm.tag == "repro://world"
    assert comm.managed
    assert comm.size() == len(jax.devices())
    assert world() is comm  # cached singleton
    assert comm.group().compare(default_session().group("repro://world")) is (
        GroupComparison.IDENT
    )


def test_from_group_validation():
    g = default_session().group("repro://world")
    with pytest.raises(errors.GroupError):
        Communicator.from_group(Group())
    with pytest.raises(errors.GroupError):
        Communicator.from_group("repro://world")  # needs a Group, not a name
    with pytest.raises(errors.DimsError):
        Communicator.from_group(g, shape=(g.size() + 1,))
    with pytest.raises(errors.DimsError):
        Communicator.from_group(g, shape=(1, g.size()))  # multi-axis needs names


def test_from_group_axis_name_from_tag():
    g = default_session().group("repro://self")
    comm = Communicator.from_group(g, tag="repro://io")
    assert comm.axis_names == ("io",)
    assert Communicator.from_group(g).axis_names == ("ranks",)


def test_create_routes_through_from_group():
    comm = Communicator.create((1,), ("w",), devices=jax.devices())
    assert comm.managed
    assert comm.group().size() == 1
    assert comm.group().devices[0] == jax.devices()[0]


def test_dup_preserves_group():
    comm = world(refresh=True)
    dup = comm.dup()
    assert dup.group().compare(comm.group()) is GroupComparison.IDENT
    assert not dup.managed


def test_session_run_spmd():
    """A communicator built from a session pset runs SPMD programs."""

    import jax.numpy as jnp

    sess = Session.init()
    comm = Communicator.from_group(sess.group("repro://world"), tag="repro://world")
    out = comm.run(lambda: comm.allreduce(jnp.float32(1.0)))
    assert float(out) == comm.size()


# ---------------------------------------------------------------------------
# multi-device: split routing, mesh psets, disjoint train/serve sets
# ---------------------------------------------------------------------------


SPLIT_CODE = textwrap.dedent("""
    import jax
    from repro.core.communicator import Communicator
    from repro.core.session import Group, GroupComparison, Session

    sess = Session.init()
    world = sess.group("repro://world")
    assert world.size() == 8

    comm = Communicator.from_group(world, tag="repro://grid", shape=(4, 2),
                                   axis_names=("data", "model"))
    # rank r in the source group IS the device at row-major position r
    assert comm.group().compare(world) is GroupComparison.IDENT

    # from_group honors the group's own device order (no topology reorder)
    rev = world.incl(list(reversed(range(8))))
    rcomm = Communicator.from_group(rev, tag="repro://rev")
    assert rcomm.group().compare(rev) is GroupComparison.IDENT

    # split along "model": 4 colors of size 2, partitioning the grid
    sub = comm.split("model")
    assert sub.size() == 2
    colors = [sub.group(data=i) for i in range(4)]
    union = Group()
    for c in colors:
        assert c.size() == 2
        assert not (union & c)          # pairwise disjoint
        union = union | c
    assert union.compare(world) is not GroupComparison.UNEQUAL

    # mesh sub-grids become named process sets
    names = sess.register_mesh_psets(comm.mesh)
    assert "repro://mesh/data/0" in names and "repro://mesh/model/1" in names
    assert sess.group("repro://mesh/data/0").size() == 2
    assert sess.group("repro://mesh/model/1").size() == 4
    assert sess.group("repro://mesh/data/0").compare(colors[0]) is not \\
        GroupComparison.UNEQUAL
    print("SPLIT_OK")
""")


def test_split_routes_through_groups_8dev(subproc):
    out = subproc(SPLIT_CODE, n=8)
    assert "SPLIT_OK" in out


DISJOINT_CODE = textwrap.dedent("""
    import jax, jax.numpy as jnp
    from repro.core.communicator import Communicator
    from repro.core.session import Session

    sess = Session.init()
    world = sess.group("repro://world")
    sess.register_pset("repro://train", world.incl(range(4)))
    sess.register_pset("repro://serve", world.excl(range(4)))

    train = Communicator.from_group(sess.group("repro://train"),
                                    tag="repro://train")
    serve = Communicator.from_group(sess.group("repro://serve"),
                                    tag="repro://serve")
    assert train.axis_names == ("train",) and serve.axis_names == ("serve",)
    assert train.size() == serve.size() == 4
    assert not (train.group() & serve.group())      # disjoint hardware

    # both run SPMD programs independently on their own process set
    assert float(train.run(lambda: train.allreduce(jnp.float32(1.0)))) == 4.0
    assert float(serve.run(lambda: serve.allreduce(jnp.float32(2.0)))) == 8.0

    # the runtime path: a Trainer whose communicator is a non-world pset
    from repro.configs.base import ModelConfig, ParallelConfig
    from repro.runtime.trainer import Trainer, TrainerConfig

    cfg = ModelConfig(name="tiny", family="dense", num_layers=1, d_model=32,
                      num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                      vocab_size=64)
    comm = Communicator.from_group(sess.group("repro://train"),
                                   tag="repro://train", shape=(4, 1),
                                   axis_names=("data", "model"))
    t = Trainer(cfg, ParallelConfig(), TrainerConfig(steps=2, log_every=1),
                comm, seq_len=32, global_batch=4)
    result = t.run()
    assert result["final_step"] == 2
    assert t.comm is comm
    assert {d.id for d in t.mesh.devices.flat} == \\
        {d.id for d in sess.pset("repro://train")}
    print("DISJOINT_OK")
""")


def test_disjoint_train_serve_psets_8dev(subproc):
    out = subproc(DISJOINT_CODE, n=8)
    assert "DISJOINT_OK" in out
