"""Virtual process topologies & neighborhood collectives (MPI 4.0 ch. 8).

Host-level cart arithmetic and graph validation run in-process; exchange
numerics (which need >1 rank) run on 8 virtual devices via ``subproc``.
"""

from __future__ import annotations

import pytest

from repro.core import errors, topology
from repro.core.topology import PROC_NULL, cart_coords_of, cart_rank_of, cart_shift_tables


# -- host-level cart arithmetic ----------------------------------------------


def test_cart_coords_rank_roundtrip():
    dims = (3, 4, 2)
    for r in range(24):
        coords = cart_coords_of(dims, r)
        assert cart_rank_of(dims, (False,) * 3, coords) == r


def test_cart_rank_periodic_wrap_and_nonperiodic_error():
    dims, periods = (4, 3), (True, False)
    assert cart_rank_of(dims, periods, (-1, 2)) == cart_rank_of(dims, periods, (3, 2))
    assert cart_rank_of(dims, periods, (5, 0)) == cart_rank_of(dims, periods, (1, 0))
    with pytest.raises(errors.RankError):
        cart_rank_of(dims, periods, (0, 3))       # non-periodic out of range
    with pytest.raises(errors.RankError):
        cart_coords_of(dims, 12)


def test_cart_shift_nonperiodic_boundary_is_proc_null():
    # the satellite case: shift(+1) on a non-periodic dim — the last rank
    # has no destination, the first no source
    srcs, dsts = cart_shift_tables((4,), (False,), 0, 1)
    assert dsts == (1, 2, 3, PROC_NULL)
    assert srcs == (PROC_NULL, 0, 1, 2)
    # periodic closes the ring
    srcs, dsts = cart_shift_tables((4,), (True,), 0, 1)
    assert dsts == (1, 2, 3, 0) and srcs == (3, 0, 1, 2)
    # multi-dim: shifting dim 1 of (2, 3) moves within each row
    srcs, dsts = cart_shift_tables((2, 3), (False, False), 1, 1)
    assert dsts == (1, 2, PROC_NULL, 4, 5, PROC_NULL)


def test_cart_create_registers_pset_and_routes_through_group():
    from repro import core as mpx

    comm = mpx.world()
    cart = topology.cart_create(comm, (1,), (True,), tag="repro://cart/t1")
    assert isinstance(cart, topology.CartComm)
    assert cart.managed and cart.tag == "repro://cart/t1"
    # the grid is a session process set now
    sess = mpx.default_session()
    assert sess.pset_info("repro://cart/t1")["mpi_size"] == 1
    # group membership matches the parent group's leading prod(dims) ranks
    assert cart.group().compare(comm.group().incl([0])).name == "IDENT"


def test_cart_create_validation():
    from repro import core as mpx

    comm = mpx.world()
    with pytest.raises(errors.DimsError):
        topology.cart_create(comm, (comm.size() + 1,))
    with pytest.raises(errors.DimsError):
        topology.cart_create(comm, (1,), (True, False))


def test_cart_create_same_grid_is_idempotent():
    from repro import core as mpx

    comm = mpx.world()
    c1 = topology.cart_create(comm, (1,), tag="repro://cart/idem")
    c2 = topology.cart_create(comm, (1,), tag="repro://cart/idem")
    assert c1.group() == c2.group()


def test_dist_graph_accepts_proc_null_placeholders():
    from repro import core as mpx

    comm = mpx.world()
    # a PROC_NULL placeholder slot is part of the documented buffer
    # contract: it keeps its position and reads zeros
    g = topology.dist_graph_create_adjacent(
        comm, sources=[[topology.PROC_NULL, 0]], destinations=[[0, topology.PROC_NULL]]
    )
    assert g.indegree(0) == 2 and g.outdegree(0) == 2
    with pytest.raises(errors.RankError):
        topology.dist_graph_create_adjacent(comm, [[5]], [[]])


def test_cart_shift_axis_perm_is_subgroup_pairs():
    from repro import core as mpx

    cart = topology.cart_create(mpx.world(), (1,), (True,))
    s = cart.cart_shift(0, 1)
    assert s.axis_name == "cart0"
    assert s.axis_perm == ((0, 0),)      # size-1 periodic ring = self edge


# -- graph validation (host-level, via the edge builder) ----------------------


def test_dist_graph_edge_consistency_required():
    # rank 0 claims an edge to 1 that rank 1 does not list
    with pytest.raises(errors.TopologyError):
        topology._build_edges(sources=[[], []], destinations=[[1], []])
    # the reverse direction: rank 1 lists an in-edge 0 never declared
    with pytest.raises(errors.TopologyError):
        topology._build_edges(sources=[[], [0, 0]], destinations=[[1], []])


def test_dist_graph_repeated_edges_pair_by_occurrence():
    edges = topology._build_edges(
        sources=[[], [0, 0]], destinations=[[1, 1], []]
    )
    assert [(e.out_slot, e.in_slot) for e in edges] == [(0, 0), (1, 1)]


def test_matching_rounds_are_legal_permutes():
    edges = topology._build_edges(
        sources=[[2], [0], [0, 1]], destinations=[[1, 2], [2], [0]]
    )
    rounds = topology._matching_rounds(edges)
    for members in rounds:
        srcs = [e.src for e in members]
        dsts = [e.dst for e in members]
        assert len(set(srcs)) == len(srcs) and len(set(dsts)) == len(dsts)
    assert sum(len(m) for m in rounds) == len(edges)


# -- cart_shift slot-pairing property (degenerate periodic dims) --------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # container without hypothesis: exhaustive fallback below
    HAVE_HYPOTHESIS = False


def _check_cart_slot_pairing(dims, periods):
    """The slot-pairing invariants of the explicit cart edge set
    (:func:`topology.cart_edges`), for any grid — including the degenerate
    size-1 (self-loop) and size-2 (+1 == −1 neighbor) periodic dims where
    occurrence-order pairing would desynchronise:

    * every edge pairs opposite slots of one dim: ``in_slot == out_slot ^ 1``;
    * the + slot (``2d+1``) sends to ``destinations[src]`` of dim ``d``, the
      − slot (``2d``) to ``sources[src]`` (the reverse shift);
    * each (rank, slot) sends exactly once and receives exactly once unless
      the slot is ``PROC_NULL`` (non-periodic boundary);
    * the matching rounds are legal permutes covering every edge once.
    """

    edges = topology.cart_edges(dims, periods)
    tables = [
        cart_shift_tables(dims, periods, d, 1) for d in range(len(dims))
    ]
    outs, ins = set(), set()
    for e in edges:
        d, plus = divmod(e.out_slot, 2)
        assert e.in_slot == e.out_slot ^ 1
        srcs, dsts = tables[d]
        assert e.dst == (dsts[e.src] if plus else srcs[e.src])
        assert (e.src, e.out_slot) not in outs, "duplicate send slot"
        assert (e.dst, e.in_slot) not in ins, "duplicate receive slot"
        outs.add((e.src, e.out_slot))
        ins.add((e.dst, e.in_slot))
    # non-NULL slots all participate, on both sides
    n = 1
    for dd in dims:
        n *= dd
    for r in range(n):
        for d, (srcs, dsts) in enumerate(tables):
            if dsts[r] != PROC_NULL:
                assert (r, 2 * d + 1) in outs
            if srcs[r] != PROC_NULL:
                assert (r, 2 * d) in outs
            # receives mirror sends: − receives from the lower neighbor
            if srcs[r] != PROC_NULL:
                assert (r, 2 * d) in ins
            if dsts[r] != PROC_NULL:
                assert (r, 2 * d + 1) in ins
    rounds = topology._matching_rounds(edges)
    assert sum(len(m) for m in rounds) == len(edges)
    for members in rounds:
        srcs = [e.src for e in members]
        dsts = [e.dst for e in members]
        assert len(set(srcs)) == len(srcs) and len(set(dsts)) == len(dsts)


if HAVE_HYPOTHESIS:

    @settings(max_examples=200, deadline=None)
    @given(
        st.lists(
            st.tuples(st.integers(1, 4), st.booleans()), min_size=1, max_size=3
        )
    )
    def test_cart_slot_pairing_property(spec):
        dims = tuple(d for d, _ in spec)
        periods = tuple(p for _, p in spec)
        _check_cart_slot_pairing(dims, periods)

else:

    @pytest.mark.parametrize("dims,periods", [
        ((1,), (True,)),                    # self-loop on both slots
        ((2,), (True,)),                    # +1 and −1 name the same rank
        ((1, 1), (True, True)),
        ((2, 2), (True, True)),
        ((1, 3), (True, True)),
        ((2, 3), (True, False)),
        ((1,), (False,)),                   # fully disconnected
        ((2, 1, 2), (True, True, True)),
        ((4, 2), (False, True)),
        ((3, 3), (True, True)),
    ])
    def test_cart_slot_pairing_property(dims, periods):
        _check_cart_slot_pairing(dims, periods)


def test_cart_slot_pairing_matches_communicator_tables(subproc):
    """The pure edge set drives the CartComm rounds: a size-2 periodic ring
    exchange must deliver the − payload to the + slot and vice versa (the
    physical check of the pairing the property asserts structurally)."""

    code = """
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro import core as mpx
from repro.core import topology

comm = mpx.world()
cart = topology.cart_create(comm, (2,), (True,))
edges = topology.cart_edges((2,), (True,))
# both ranks: − slot (0) and + slot (1) both name the other rank; the
# pairing must still route − sends into + slots
for e in edges:
    assert e.in_slot == e.out_slot ^ 1, e

def ex(x):
    r = cart.rank().astype(jnp.float32)
    # slot 0 (−) payload = rank, slot 1 (+) payload = rank + 10
    return cart.neighbor_alltoall(jnp.stack([r, r + 10.0])).get()

out = np.asarray(
    cart.spmd(ex, out_specs=P("cart0"))(jnp.zeros((), jnp.float32))
).reshape(2, 2)
# slot 0 (−) receives the lower neighbor's + send (neighbor + 10); slot 1
# (+) receives the upper neighbor's − send (neighbor).  On the 2-ring the
# neighbor is 1 − r both ways — occurrence-order pairing would swap these.
for r in range(2):
    assert out[r, 0] == (1 - r) + 10, out
    assert out[r, 1] == (1 - r), out
print("PAIRING_OK")
"""
    assert "PAIRING_OK" in subproc(code, n=2)


# -- exchange numerics & group algebra (8 virtual devices) --------------------


def test_cart_exchange_numerics_and_cart_sub(subproc):
    code = """
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import core as mpx
from repro.core import topology

comm = mpx.world()
assert comm.size() == 8

# null neighbors read zero at the non-periodic boundary
cart = topology.cart_create(comm, (8,), (False,))
def ag(x):
    return cart.neighbor_allgather(x + 1.0 + cart.rank().astype(x.dtype)).get()
out = np.asarray(cart.spmd(ag, out_specs=P("cart0"))(jnp.zeros((), jnp.float32)))
out = out.reshape(8, 2)
exp = np.array([[r if r > 0 else 0, r + 2 if r < 7 else 0] for r in range(8)], float)
assert np.allclose(out, exp), (out, exp)

# cart_sub group algebra vs Group.incl: (2, 4) grid, keep dim 1
cart2 = topology.cart_create(comm, (2, 4), (False, True), tag="repro://cart/2x4t")
sub = cart2.cart_sub([False, True])
assert sub.dims == (4,) and sub.periods == (True,)
g_row1 = sub.group(cart0=1)
expect = cart2.group().incl([4, 5, 6, 7])
assert g_row1.compare(expect).name == "IDENT", (g_row1.devices, expect.devices)
# and the retained-dim shift still works on the sub communicator
s = sub.cart_shift(0, 1)
assert s.axis_perm == ((0, 1), (1, 2), (2, 3), (3, 0))

# the default dims-keyed tag must not clobber a different group's grid
cart_a = topology.cart_create(comm.group().incl([0, 1]), (2,))
try:
    topology.cart_create(comm.group().incl([2, 3]), (2,))
    raise SystemExit("expected ERR_ARG on cart pset clobber")
except Exception as e:
    assert "ARG" in type(e).__name__.upper() or "ERR_ARG" in str(e), e
topology.cart_create(comm.group().incl([2, 3]), (2,), tag="repro://cart/2b")

# shift_exchange TraceFuture chains then() into the request engine
def chain(x):
    fut = cart.shift_exchange(x + cart.rank().astype(x.dtype), 0, 1)
    return fut.then(lambda f: f.get() * 2.0).get()[None]
out = np.asarray(cart.spmd(chain, out_specs=P("cart0"))(jnp.zeros((), jnp.float32)))
exp = np.array([0.0] + [2.0 * r for r in range(7)])   # rank 0 boundary = zeros
assert np.allclose(out, exp), out
print("TOPOLOGY_CART_OK")
"""
    assert "TOPOLOGY_CART_OK" in subproc(code, n=8)


def test_dist_graph_asymmetric_degrees_and_alltoallv_vs_dense(subproc):
    code = """
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import core as mpx
from repro.core import topology

comm = mpx.world()
N = comm.size()

# asymmetric in/out degrees: a fan-in star (everyone -> rank 0) plus a
# chain edge 0 -> 1
srcs = [[1, 2, 3, 4, 5, 6, 7], [0], [], [], [], [], [], []]
dsts = [[1], [0], [0], [0], [0], [0], [0], [0]]
g = topology.dist_graph_create_adjacent(comm, srcs, dsts)
assert g.indegree(0) == 7 and g.outdegree(0) == 1
assert g.dist_graph_neighbors_count(3) == (0, 1)
assert g.indegree() == 7 and g.outdegree() == 1    # padded SPMD maxima

def star(x):
    r = g.rank().astype(jnp.float32)
    return g.neighbor_alltoall((x + 1.0 + r)[None]).get()
out = np.asarray(g.spmd(star, out_specs=P("world"))(jnp.zeros((), jnp.float32)))
out = out.reshape(N, 7)
assert np.allclose(out[0], [2, 3, 4, 5, 6, 7, 8]), out[0]   # rank 0 hears all
assert np.allclose(out[1][0], 1.0)                           # rank 1 hears 0
assert np.allclose(out[2:], 0.0)                             # others: nothing

# neighbor_alltoallv numerics vs a dense alltoall reference on the full
# graph (every rank neighbors every rank, in rank order)
full = [list(range(N)) for _ in range(N)]
gf = topology.dist_graph_create_adjacent(comm, full, full)
C, D = 3, 2
counts = [[C] * N] * N
def nv(v):
    blocks, rc = gf.neighbor_alltoallv(v.reshape(N, C, D), counts).get()
    return blocks
def dense(v):
    return jax.lax.all_to_all(v, "world", 0, 0, tiled=True)
x = jnp.arange(N * N * C * D, dtype=jnp.float32).reshape(N * N * C, D)
got = np.asarray(gf.spmd(nv, in_specs=P("world"), out_specs=P("world"))(x))
ref = np.asarray(comm.spmd(dense, in_specs=P("world"), out_specs=P("world"))(x))
assert np.allclose(got.reshape(ref.shape), ref), (got, ref)
print("TOPOLOGY_GRAPH_OK")
"""
    assert "TOPOLOGY_GRAPH_OK" in subproc(code, n=8)


def test_size2_periodic_cart_alltoallv_counts(subproc):
    """Regression: on a size-2 (or size-1) periodic dim both neighbor slots
    name the same rank; the recv-count table must follow the cart slot
    pairing (− send lands in the + slot), not occurrence order — the bug
    returned padding as valid data and masked real rows."""

    code = """
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import core as mpx
from repro.core import topology

comm = mpx.Communicator.create((2,), ("r",))
cart = topology.cart_create(comm, (2,), (True,))

def nv(x):
    r = cart.rank().astype(jnp.float32)
    blocks = (jnp.arange(6, dtype=jnp.float32).reshape(2, 3) + 1.0 + 10.0 * r)
    out, rc = cart.neighbor_alltoallv(blocks[..., None], [3, 1]).get()
    return out[..., 0], rc
out, rc = cart.spmd(nv, out_specs=(P("cart0"), P("cart0")))(jnp.zeros((), jnp.float32))
out = np.asarray(out).reshape(2, 2, 3)
rc = np.asarray(rc).reshape(2, 2)
# my − slot (0) receives the peer's + slot (1) block, valid count 1;
# my + slot (1) receives the peer's − slot (0) block, valid count 3
assert np.array_equal(rc, [[1, 3], [1, 3]]), rc
assert np.allclose(out[0, 0], [14, 0, 0]), out[0]   # rank1 slot-1 row, count 1
assert np.allclose(out[0, 1], [11, 12, 13]), out[0]  # rank1 slot-0 rows, count 3
assert np.allclose(out[1, 0], [4, 0, 0]), out[1]
assert np.allclose(out[1, 1], [1, 2, 3]), out[1]

# size-1 periodic self-ring: both slots are self edges
cart1 = topology.cart_create(comm.group().incl([0]), (1,), (True,),
                             tag="repro://cart/selfring")
def nv1(x):
    blocks = jnp.arange(4, dtype=jnp.float32).reshape(2, 2) + 1.0
    out, rc = cart1.neighbor_alltoallv(blocks[..., None], [2, 1]).get()
    return out[..., 0], rc
out1, rc1 = cart1.spmd(nv1)(jnp.zeros((), jnp.float32))
assert np.array_equal(np.asarray(rc1), [1, 2]), rc1
assert np.allclose(np.asarray(out1), [[3, 0], [1, 2]]), out1
print("CART_SIZE2_OK")
"""
    assert "CART_SIZE2_OK" in subproc(code, n=2)


def test_persistent_neighbor_alltoall_and_moe_dispatch(subproc):
    code = """
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import core as mpx
from repro.core import topology, tool
from repro.models import mlp
from repro.configs.base import ModelConfig

comm = mpx.world()
N = comm.size()
cart = topology.cart_create(comm, (N,), (True,))

# persistent neighborhood collective: AOT once, MPI_Start re-fires
req = cart.neighbor_alltoall_init(jax.ShapeDtypeStruct((2, 8), jnp.float32))
before = tool.pvar_read().get("persistent_init", 0)
for i in range(3):
    out = req.start(jnp.full((2, 8), float(i))).get()
assert req.starts == 3
assert tool.pvar_read().get("persistent_init", 0) == before  # no re-init
assert tool.pvar_read().get("neighbor_alltoall_init", 0) >= 1

# MoE expert dispatch over the router's expert-map graph (full graph ==
# exact dense top-k mixture; ample capacity => no drops)
cfg = ModelConfig(name="t", family="moe", num_layers=2, d_model=16, num_heads=2,
                  num_kv_heads=2, head_dim=8, d_ff=32, vocab_size=64,
                  num_experts=2 * N, moe_top_k=2, moe_d_ff=24)
p = mlp.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
srcs, dsts = mlp.expert_dispatch_graph(N, 2 * N)
g = topology.dist_graph_create_adjacent(comm, srcs, dsts)
T = 4 * N
xt = jax.random.normal(jax.random.PRNGKey(2), (T, 16))

def run(xl, router, wg, wu, wd):
    y, aux = mlp.moe_neighbor(
        {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd}, xl, cfg, g)
    return y, aux["dropped_fraction"]
y, dropped = g.spmd(
    run,
    in_specs=(P("world"), P(), P("world"), P("world"), P("world")),
    out_specs=(P("world"), P()),
)(xt, p["router"], p["w_gate"], p["w_up"], p["w_down"])
assert float(dropped) == 0.0

logits = np.asarray(xt.astype(jnp.float32) @ p["router"])
pr = np.asarray(jax.nn.softmax(jnp.asarray(logits), axis=-1))
topk = np.argsort(-pr, axis=-1)[:, :2]
gates = np.take_along_axis(pr, topk, axis=-1)
gates = gates / gates.sum(-1, keepdims=True)
act = lambda v: np.asarray(jax.nn.silu(jnp.asarray(v)))
y_exp = np.zeros((T, 16))
for i in range(T):
    for j in range(2):
        e = topk[i, j]
        v = np.asarray(xt[i])
        h = act(v @ np.asarray(p["w_gate"][e])) * (v @ np.asarray(p["w_up"][e]))
        y_exp[i] += gates[i, j] * (h @ np.asarray(p["w_down"][e]))
err = np.abs(np.asarray(y) - y_exp).max()
assert err < 1e-4, err

# device-limited routing (radius 1) stays sparse: no all-to-all in the HLO
srcs1, dsts1 = mlp.expert_dispatch_graph(N, 2 * N, radius=1)
g1 = topology.dist_graph_create_adjacent(comm, srcs1, dsts1)
def run1(xl, router, wg, wu, wd):
    y, _ = mlp.moe_neighbor(
        {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd}, xl, cfg, g1)
    return y
from repro.analysis import hlo as hlo_passes
c = jax.jit(g1.spmd(run1, in_specs=(P("world"), P(), P("world"), P("world"),
                                    P("world")), out_specs=P("world"),
                    jit=False)).lower(
    jax.ShapeDtypeStruct((T, 16), jnp.float32),
    *(jax.ShapeDtypeStruct(np.shape(v), jnp.float32)
      for v in (p["router"], p["w_gate"], p["w_up"], p["w_down"]))).compile()
assert hlo_passes.no_collective(c, "all-to-all").ok, hlo_passes.stats_dict(c)
assert hlo_passes.collective_stats(c).count.get("collective-permute", 0) > 0

# top-k wider than the graph's reach is a setup error, not silent corruption
cfg1 = ModelConfig(name="t1", family="moe", num_layers=2, d_model=16,
                   num_heads=2, num_kv_heads=2, head_dim=8, d_ff=32,
                   vocab_size=64, num_experts=N, moe_top_k=2, moe_d_ff=24)
p1 = mlp.init_moe(jax.random.PRNGKey(0), cfg1, jnp.float32)
s0, d0 = mlp.expert_dispatch_graph(N, N, radius=0)    # self-only: 1 expert
g0 = topology.dist_graph_create_adjacent(comm, s0, d0)
try:
    g0.spmd(lambda xl, r_, wg, wu, wd: mlp.moe_neighbor(
        {"router": r_, "w_gate": wg, "w_up": wu, "w_down": wd},
        xl, cfg1, g0)[0],
        in_specs=(P("world"), P(), P("world"), P("world"), P("world")),
        out_specs=P("world"))(
        xt, p1["router"], p1["w_gate"], p1["w_up"], p1["w_down"])
    raise SystemExit("expected ERR_TOPOLOGY for top-k > reachable experts")
except Exception as e:
    assert "TOPOLOGY" in str(e).upper(), e
print("TOPOLOGY_MOE_OK")
"""
    assert "TOPOLOGY_MOE_OK" in subproc(code, n=4)
