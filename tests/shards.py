"""Tier-1 CI shard definitions.

The CI matrix splits tier-1 into a core shard (the repro.core interface
layers, fast and mostly in-process), a kernels shard (Pallas kernels and
their oracles — interpret-mode compute-heavy) and a runtime shard
(trainer/server integration, models, dry-run — the subprocess-heavy half),
so the legs run in parallel.  ``--check`` verifies the shards partition the
real test file set, so a new test file cannot silently fall out of CI.

    python tests/shards.py core          # print the shard's files
    python tests/shards.py --check      # verify coverage & disjointness
"""

from __future__ import annotations

import sys
from pathlib import Path

SHARDS = {
    "core": [
        "tests/test_analysis.py",
        "tests/test_analysis_hlo.py",
        "tests/test_cell_specs.py",
        "tests/test_collectives.py",
        "tests/test_datatypes.py",
        "tests/test_epoch.py",
        "tests/test_errors_and_tool.py",
        "tests/test_futures.py",
        "tests/test_hloanalysis.py",
        "tests/test_io.py",
        "tests/test_onesided.py",
        "tests/test_overlap.py",
        "tests/test_requests.py",
        "tests/test_session.py",
        "tests/test_sharding_rules.py",
        "tests/test_topology.py",
    ],
    "kernels": [
        "tests/test_kernels.py",
        "tests/test_ring_attention.py",
    ],
    "runtime": [
        "tests/test_checkpoint.py",
        "tests/test_data_pipeline.py",
        "tests/test_distributed_paths.py",
        "tests/test_dryrun_integration.py",
        "tests/test_elastic_multidevice.py",
        "tests/test_elastic_runtime.py",
        "tests/test_engine.py",
        "tests/test_models.py",
        "tests/test_server.py",
        "tests/test_trainer.py",
        "tests/test_tune.py",
    ],
}


def check() -> int:
    root = Path(__file__).resolve().parents[1]
    actual = {f"tests/{p.name}" for p in (root / "tests").glob("test_*.py")}
    listed: list[str] = [f for files in SHARDS.values() for f in files]
    dupes = {f for f in listed if listed.count(f) > 1}
    missing = actual - set(listed)
    stale = set(listed) - actual
    ok = not (dupes or missing or stale)
    if dupes:
        print(f"files in more than one shard: {sorted(dupes)}", file=sys.stderr)
    if missing:
        print(f"test files missing from every shard: {sorted(missing)}", file=sys.stderr)
    if stale:
        print(f"shard entries with no matching file: {sorted(stale)}", file=sys.stderr)
    if ok:
        print(f"shards cover all {len(actual)} test files, disjointly")
    return 0 if ok else 1


def main(argv: list[str]) -> int:
    if argv == ["--check"]:
        return check()
    if len(argv) == 1 and argv[0] in SHARDS:
        print(" ".join(SHARDS[argv[0]]))
        return 0
    print(f"usage: shards.py --check | {{{','.join(SHARDS)}}}", file=sys.stderr)
    return 2


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
