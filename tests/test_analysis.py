"""repro.analysis — the MUST-style communication-correctness analyzer.

Seeded-defect suite: every checker must fire on its defect with the correct
:class:`~repro.core.errors.ErrorClass`, and must stay silent on the clean
variant of the same program.  Defects that cannot be produced through the
normal API (the runtime forbids them — e.g. cross-epoch puts, which
``Window.fence`` drains before the epoch increments) are seeded through the
events API directly: the ledger IS the interposition surface, exactly as
MUST consumes PMPI event streams rather than the application source.

Also here: the pvar-registry meta-check (every counter written anywhere in
the tree is registered in ``tool.PVARS`` — static half over literal names,
runtime half via ``pvar_strict``), the repo-wide swallowed-failure check,
and the deadlock-detector property test (flags all and only the cyclic
sync schedules; hypothesis when available, exhaustive fallback otherwise —
same precedent as the cart slot-pairing property in test_topology.py).
"""

from __future__ import annotations

import itertools

import numpy as np
import pytest

from repro.analysis import checkers, events, static
from repro.core import errors, tool
from repro.core.errors import ErrorClass

ROUND = [(0, 1), (1, 2), (2, 0)]          # 3-cycle permutation


@pytest.fixture()
def recording():
    """Fresh ledger with recording on (via the cvar, so the MPI_T path is
    exercised); everything restored afterwards."""

    events.reset()
    tool.cvar_set("analysis_recording", True)
    try:
        yield events.ledger()
    finally:
        tool.cvar_set("analysis_recording", False)
        events.reset()


def codes(findings, check=None):
    return [f.code for f in findings if check is None or f.check == check]


# ---------------------------------------------------------------------------
# recording toggle
# ---------------------------------------------------------------------------


def test_recording_off_by_default():
    assert tool.cvar_get("analysis_recording") is False
    assert events.RECORDING is False
    before = len(events.ledger())
    events.record_collective("c", "allreduce", rank=0)
    assert len(events.ledger()) == before, "recorded while disabled"


def test_cvar_toggles_recording(recording):
    assert events.RECORDING is True
    events.record_collective("c", "allreduce", rank=0)
    assert len(events.ledger()) == 1
    tool.cvar_set("analysis_recording", False)
    events.record_collective("c", "allreduce", rank=0)
    assert len(events.ledger()) == 1
    tool.cvar_set("analysis_recording", True)   # fixture teardown expects on/off pairs to be safe


# ---------------------------------------------------------------------------
# (a) collective order / signature
# ---------------------------------------------------------------------------


def test_clean_collective_order(recording):
    for r in range(4):
        events.record_collective("c", "allreduce", np.zeros(3, np.float32), rank=r)
        events.record_collective("c", "allgather", np.zeros(3, np.float32), rank=r)
    assert checkers.check_collective_order() == []


def test_mismatched_collective_order(recording):
    events.record_collective("c", "allreduce", rank=0)
    events.record_collective("c", "allgather", rank=0)
    events.record_collective("c", "allgather", rank=1)   # swapped on rank 1
    events.record_collective("c", "allreduce", rank=1)
    f = checkers.check_collective_order()
    assert codes(f, "collective-order") == [ErrorClass.ERR_NOT_SAME]


def test_mismatched_collective_signature(recording):
    events.record_collective("c", "allreduce", np.zeros(3, np.float32), rank=0)
    events.record_collective("c", "allreduce", np.zeros(3, np.int32), rank=1)
    f = checkers.check_collective_order()
    assert codes(f, "collective-signature") == [ErrorClass.ERR_NOT_SAME]


def test_collective_count_mismatch(recording):
    events.record_collective("c", "allreduce", rank=0)
    events.record_collective("c", "allreduce", rank=1)
    events.record_collective("c", "allreduce", rank=0)   # rank 1 never re-enters
    f = checkers.check_collective_order()
    assert codes(f, "collective-order") == [ErrorClass.ERR_NOT_SAME]


# ---------------------------------------------------------------------------
# (b) deadlock
# ---------------------------------------------------------------------------


def test_sendrecv_ring_is_clean(recording):
    # the combined MPI_Sendrecv form completes round-atomically: every ring
    # schedule is a legal cycle
    events.record_p2p_round("c", ROUND, mode="sendrecv", size=3)
    assert checkers.check_deadlock() == []


def test_sync_cycle_deadlocks(recording):
    events.record_p2p_round("c", ROUND, mode="sync", size=3)
    f = checkers.check_deadlock()
    assert codes(f, "deadlock") == [ErrorClass.ERR_PENDING]
    assert "wait-for cycle" in f[0].message


def test_unmatched_send(recording):
    events.record_p2p("send", 0, 1, comm="c")
    f = checkers.check_deadlock()
    assert codes(f, "unmatched-p2p") == [ErrorClass.ERR_PENDING]


def test_matched_send_recv_stream(recording):
    events.record_p2p("send", 0, 1, comm="c")
    events.record_p2p("recv", 1, 0, comm="c")
    assert checkers.check_deadlock() == []


def test_illegal_matching_round(recording):
    events.record_p2p_round("c", [(0, 1), (0, 2)], mode="sendrecv", size=3)
    f = checkers.check_deadlock()
    assert codes(f, "matching-round") == [ErrorClass.ERR_RANK]


# ---------------------------------------------------------------------------
# (b') deadlock property: all and only the cyclic sync schedules
# ---------------------------------------------------------------------------


def _partial_perms(n):
    """Every injective partial map on {0..n-1} as an edge list."""

    ranks = range(n)
    for k in range(n + 1):
        for srcs in itertools.combinations(ranks, k):
            for dsts in itertools.permutations(ranks, k):
                yield tuple(zip(srcs, dsts))


def _has_cycle(perm):
    nxt = dict(perm)
    for start in nxt:
        seen = set()
        r = start
        while r in nxt:
            if r in seen:
                return True
            seen.add(r)
            r = nxt[r]
    return False


def _check_deadlock_property(schedule):
    """The detector flags ERR_PENDING/deadlock iff some sync round of the
    schedule is cyclic — and stays silent otherwise (no false positives on
    acyclic sync rounds or any sendrecv round)."""

    events.reset()
    prev = events.set_recording(True)
    try:
        for mode, perm in schedule:
            events.record_p2p_round("c", perm, mode=mode, size=4)
    finally:
        events.set_recording(prev)
    f = checkers.check_deadlock()
    events.reset()
    expected = any(m == "sync" and _has_cycle(p) for m, p in schedule)
    flagged = any(x.check == "deadlock" for x in f)
    assert flagged == expected, (schedule, [str(x) for x in f])
    if expected:
        assert ErrorClass.ERR_PENDING in codes(f, "deadlock")
    else:
        assert f == [], (schedule, [str(x) for x in f])


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # container without hypothesis: exhaustive fallback below
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    _perm_st = st.builds(
        lambda pairs: tuple(zip([s for s, _ in pairs], [d for _, d in pairs])),
        st.lists(st.tuples(st.integers(0, 3), st.integers(0, 3)), max_size=4),
    ).filter(
        lambda p: len({s for s, _ in p}) == len(p)
        and len({d for _, d in p}) == len(p)
    )

    @settings(max_examples=300, deadline=None)
    @given(st.lists(
        st.tuples(st.sampled_from(["sync", "sendrecv"]), _perm_st),
        min_size=1, max_size=3,
    ))
    def test_deadlock_detector_property(schedule):
        _check_deadlock_property(schedule)

else:

    @pytest.mark.parametrize("perm", list(_partial_perms(3)))
    @pytest.mark.parametrize("mode", ["sync", "sendrecv"])
    def test_deadlock_detector_exhaustive_single_round(mode, perm):
        _check_deadlock_property([(mode, perm)])

    @pytest.mark.parametrize("schedule", [
        # acyclic sync chain after a legal sendrecv ring
        [("sendrecv", ((0, 1), (1, 2), (2, 0))), ("sync", ((0, 1), (1, 2)))],
        # cycle buried in the second round
        [("sync", ((0, 1),)), ("sync", ((1, 2), (2, 1)))],
        # self-loop is a 1-cycle
        [("sync", ((2, 2),))],
        # reversal across rounds is fine: round 1 completes before round 2
        [("sync", ((0, 1),)), ("sync", ((1, 0),))],
        # the same ring is legal combined, fatal unbuffered
        [("sendrecv", ((0, 1), (1, 0))), ("sync", ((0, 1), (1, 0)))],
    ])
    def test_deadlock_detector_exhaustive_multi_round(schedule):
        _check_deadlock_property(schedule)


# ---------------------------------------------------------------------------
# (c) future / request lifecycle
# ---------------------------------------------------------------------------


def test_dangling_future(recording):
    t = events.next_token()
    events.record_future_create(t, "immediate_allreduce")
    f = checkers.check_future_lifecycle()
    assert codes(f, "dangling-future") == [ErrorClass.ERR_REQUEST]
    assert "immediate_allreduce" in f[0].message


def test_consumed_future_clean(recording):
    t = events.next_token()
    events.record_future_create(t, "immediate_allreduce")
    events.record_future_consume(t, "get")
    assert checkers.check_future_lifecycle() == []


def test_donated_start_race(recording):
    t = events.next_token()
    events.record_persistent_init(t, donated=True)
    events.record_persistent_start(
        t, donated=True, prev_outstanding=True, has_continuations=True)
    f = checkers.check_future_lifecycle()
    assert codes(f, "donated-start-race") == [ErrorClass.ERR_BUFFER]


def test_donated_start_sequential_clean(recording):
    t = events.next_token()
    events.record_persistent_init(t, donated=True)
    for _ in range(3):
        events.record_persistent_start(
            t, donated=True, prev_outstanding=False, has_continuations=False)
    assert checkers.check_future_lifecycle() == []


# ---------------------------------------------------------------------------
# (d) RMA epochs
# ---------------------------------------------------------------------------


def test_cross_epoch_put(recording):
    # unreachable through the public API (fence drains pending puts before
    # the epoch increments) — seeded at the ledger layer, the MUST idiom
    events.record_rma_apply(1, issue_epoch=0, apply_epoch=2)
    f = checkers.check_rma_epochs()
    assert codes(f, "cross-epoch-put") == [ErrorClass.ERR_WIN]


def test_same_epoch_put_clean(recording):
    events.record_rma_apply(1, issue_epoch=1, apply_epoch=1)
    assert checkers.check_rma_epochs() == []


def test_attach_detach_imbalance(recording):
    events.record_rma_pages("rma_attach", 7, 3)
    f = checkers.check_rma_epochs()
    assert codes(f, "attach-detach-imbalance") == [ErrorClass.ERR_RMA_ATTACH]
    events.record_rma_pages("rma_detach", 7, 3)
    assert checkers.check_rma_epochs() == []


# ---------------------------------------------------------------------------
# (e) I/O and checkpoint joins
# ---------------------------------------------------------------------------


def test_open_split_collective(recording):
    events.record_io_split("io_split_begin", "/tmp/f.bin", "write_at_all")
    f = checkers.check_io_joins()
    assert codes(f, "split-collective-open") == [ErrorClass.ERR_IO]
    events.record_io_split("io_split_end", "/tmp/f.bin", "write_at_all")
    assert checkers.check_io_joins() == []


def test_unjoined_checkpoint_save(recording):
    events.record_ckpt("ckpt_save", 1, 0)
    f = checkers.check_io_joins()
    assert codes(f, "unjoined-save") == [ErrorClass.ERR_IO]
    events.record_ckpt("ckpt_join", 1)
    assert checkers.check_io_joins() == []


# ---------------------------------------------------------------------------
# integration: recording through the real interface (8 virtual devices)
# ---------------------------------------------------------------------------


def test_interface_recording_end_to_end(subproc):
    out = subproc("""
import jax.numpy as jnp
from repro import core as mpx
from repro.analysis import checkers, events
from repro.core import tool

tool.cvar_set("analysis_recording", True)
comm = mpx.world()
perm = [(i, (i + 1) % comm.size()) for i in range(comm.size())]

def prog(x):
    y = comm.allreduce(x)
    y = comm.send_recv(y, perm)
    return y + comm.immediate_allreduce(x).get()

comm.spmd(prog)(jnp.ones(8))
assert len(events.ledger()) > 0, "interface recorded nothing"
findings = checkers.run_all()
assert findings == [], [str(f) for f in findings]
print("CLEAN_OK", len(events.ledger()))

def leak(x):
    comm.immediate_allreduce(x)      # never consumed
    return x

comm.spmd(leak)(jnp.ones(8))
f = [x for x in checkers.run_all() if x.check == "dangling-future"]
assert len(f) == 1 and f[0].code.name == "ERR_REQUEST", [str(x) for x in f]
assert "immediate_allreduce" in f[0].message
print("DANGLING_OK")
""")
    assert "CLEAN_OK" in out and "DANGLING_OK" in out


# ---------------------------------------------------------------------------
# meta-checks: pvar registry and swallowed failures, repo-wide
# ---------------------------------------------------------------------------


def test_every_written_pvar_is_registered():
    f = static.unregistered_pvars(["src", "benchmarks"])
    assert f == [], [str(x) for x in f]


def test_no_swallowed_failures_repo_wide():
    f = static.swallowed_failures(["src", "benchmarks"])
    assert f == [], [str(x) for x in f]


def test_pvar_strict_rejects_unregistered():
    prev = tool.pvar_strict(True)
    try:
        with pytest.raises(errors.Error) as ei:
            tool.pvar_count("definitely_not_a_registered_pvar")
        assert ei.value.klass == ErrorClass.ERR_ARG
        tool.pvar_count("persistent_start")     # registered: still fine
    finally:
        tool.pvar_strict(prev)


def test_static_scan_flags_seeded_defects(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(
        "from repro.core import tool\n"
        "try:\n    pass\nexcept Exception:\n    pass\n"
        "tool.pvar_count('never_registered_xyz')\n"
    )
    f = static.run_static([str(tmp_path)])
    assert ErrorClass.ERR_OTHER in codes(f, "swallowed-failure")
    assert ErrorClass.ERR_ARG in codes(f, "unregistered-pvar")
    ok = tmp_path / "ok.py"
    ok.write_text(
        "try:\n    pass\n"
        "except Exception:  # lint: allow-broad-except — reraised below\n"
        "    raise\n"
    )
    assert static.swallowed_failures([str(ok)]) == []
