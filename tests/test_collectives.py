"""Collective correctness against numpy oracles on an 8-virtual-device world
(subprocess; the main pytest process keeps 1 device).  Covers the mpiBench
operation set the paper benchmarks, plus user-defined aggregates through
every collective (paper Listing 1) and sub-communicator splits."""

from __future__ import annotations

import textwrap


CODE_COLLECTIVES = textwrap.dedent("""
    import dataclasses
    import jax, jax.numpy as jnp
    import numpy as np
    from repro import core as mpx

    comm = mpx.world()
    N = comm.size()
    assert N == 8

    ranks = np.arange(N, dtype=np.float32)

    # --- allreduce / reduce ------------------------------------------------
    @comm.spmd
    def allreduce_sum():
        return comm.allreduce(jnp.float32(comm.rank()))
    assert float(allreduce_sum()) == ranks.sum()

    @comm.spmd
    def allreduce_max():
        return comm.allreduce(jnp.float32(comm.rank()), op=mpx.ReduceOp.MAX)
    assert float(allreduce_max()) == ranks.max()

    @comm.spmd
    def reduce_to_root():
        return comm.reduce(jnp.float32(comm.rank()), root=2)
    # every shard returns; root semantics checked by value
    assert float(reduce_to_root()) == ranks.sum()

    # --- broadcast -----------------------------------------------------------
    @comm.spmd
    def bcast():
        val = jnp.where(comm.rank() == 3, jnp.float32(42.0), jnp.float32(0.0))
        return comm.broadcast(val, root=3)
    assert float(bcast()) == 42.0

    # --- allgather / gather ----------------------------------------------------
    @comm.spmd
    def allgather():
        return comm.allgather(jnp.full((2,), comm.rank(), jnp.float32))
    out = np.asarray(allgather())
    np.testing.assert_array_equal(out.reshape(N, 2)[:, 0], ranks)

    # --- scatter ---------------------------------------------------------------
    @comm.spmd
    def scatter():
        table = jnp.arange(N * 3, dtype=jnp.float32).reshape(N, 3)
        return comm.scatter(table, root=0)

    # block size N/N = 1 along axis 0 → every rank holds a (1, 3) block
    out = scatter()
    assert out.shape == (1, 3)

    # --- alltoall ----------------------------------------------------------------
    @comm.spmd
    def alltoall():
        block = jnp.full((N, 2), comm.rank(), jnp.float32)
        return comm.alltoall(block)
    out = alltoall()
    # row j of every rank's result is rank j's block
    np.testing.assert_array_equal(np.asarray(out)[:, 0], ranks)

    # --- reduce_scatter -------------------------------------------------------------
    @comm.spmd
    def rscatter():
        block = jnp.ones((N, 4), jnp.float32) * (comm.rank() + 1)
        return comm.reduce_scatter(block)
    out = rscatter()
    assert out.shape == (1, 4)
    np.testing.assert_array_equal(np.asarray(out), np.full((1, 4), ranks.sum() + N))

    # --- scan / exscan ----------------------------------------------------------------
    @comm.spmd
    def scan_sum():
        return comm.scan(jnp.float32(comm.rank()))
    # rank 0 shard value = 0, full value on last rank = sum; spmd returns shard 0 view
    v = scan_sum()
    assert v.shape == ()

    # --- sendrecv (shift by 1) --------------------------------------------------------
    @comm.spmd
    def shift():
        return comm.shift(jnp.float32(comm.rank()), offset=1)
    v = float(shift())
    assert v == float(N - 1)  # rank 0 received from rank N-1

    # --- barrier ---------------------------------------------------------------------
    @comm.spmd
    def barrier():
        comm.barrier()
        return jnp.int32(1)
    assert int(barrier()) == 1

    # --- aggregates through collectives (Listing 1) -------------------------------------
    @dataclasses.dataclass
    class Particle:
        pos: jax.Array
        vel: jax.Array
        mass: jax.Array

    mpx.register_aggregate(Particle)

    @comm.spmd
    def aggregate_allreduce():
        p = Particle(
            pos=jnp.ones((3,), jnp.float32),
            vel=jnp.full((3,), comm.rank(), jnp.float32),
            mass=jnp.float32(1.0),
        )
        return comm.allreduce(p)
    p = aggregate_allreduce()
    np.testing.assert_array_equal(np.asarray(p.pos), np.full(3, N, np.float32))
    np.testing.assert_array_equal(np.asarray(p.vel), np.full(3, ranks.sum()))
    assert float(p.mass) == N

    # --- sub-communicators (split) ---------------------------------------------------------
    grid = mpx.Communicator.create((2, 4), ("row", "col"))
    rows = grid.split("row")
    cols = grid.split("col")
    assert rows.size() == 2 and cols.size() == 4

    @grid.spmd
    def row_sum():
        return rows.allreduce(jnp.float32(1.0)), cols.allreduce(jnp.float32(1.0))
    r, c = row_sum()
    assert float(r) == 2.0 and float(c) == 4.0

    print("COLLECTIVES_OK")
""")


def test_collectives_8dev(subproc):
    out = subproc(CODE_COLLECTIVES, n=8)
    assert "COLLECTIVES_OK" in out


CODE_LISTING2 = textwrap.dedent("""
    import jax, jax.numpy as jnp
    from repro import core as mpx

    comm = mpx.world()

    @comm.spmd
    def listing2():
        data = jnp.where(comm.rank() == 0, jnp.int32(1), jnp.int32(0))
        f = mpx.future(comm.immediate_broadcast(data, root=0))
        f = f.then(lambda fut: comm.immediate_broadcast(
            jnp.where(comm.rank() == 1, fut.get() + 1, fut.get()), root=1))
        f = f.then(lambda fut: comm.immediate_broadcast(
            jnp.where(comm.rank() == 2, fut.get() + 1, fut.get()), root=2))
        return f.get()

    assert int(listing2()) == 3, listing2()
    print("LISTING2_OK")
""")


def test_paper_listing2_multidevice(subproc):
    """The paper's Listing 2 verbatim semantics across real (virtual) ranks:
    data == 3 on all ranks after the broadcast chain."""

    out = subproc(CODE_LISTING2, n=8)
    assert "LISTING2_OK" in out


CODE_ONESIDED = textwrap.dedent("""
    import jax, jax.numpy as jnp
    import numpy as np
    from repro import core as mpx

    comm = mpx.world()
    N = comm.size()

    @comm.spmd
    def rma():
        win = mpx.create_window(comm, jnp.full((4,), comm.rank(), jnp.float32))
        win.fence()
        # ring read: every rank reads its left neighbour's buffer
        got = win.get([((d - 1) % N, d) for d in range(N)])
        # rank 1 overwrites rank 0's window
        win.put(jnp.full((4,), 99.0, jnp.float32), [(1, 0)])
        # all ranks accumulate ones into rank 2's window
        win.accumulate(jnp.ones((4,), jnp.float32), target=2)
        win.fence()
        return got, win.buffer

    got, buf = rma()
    # rank 0 read rank N-1's buffer
    np.testing.assert_array_equal(np.asarray(got), np.full(4, float(N - 1)))
    # shard 0 of the buffer belongs to rank 0: overwritten with 99
    np.testing.assert_array_equal(np.asarray(buf), np.full(4, 99.0))
    print("ONESIDED_OK")
""")


def test_onesided_8dev(subproc):
    out = subproc(CODE_ONESIDED, n=8)
    assert "ONESIDED_OK" in out
