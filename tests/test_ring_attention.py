"""Ring attention (``kernels/ring_attention``): the fused cart-ring +
flash-attention path.  Single-device tests exercise the step kernel against
its jnp twin and the n=1 degenerate ring; the shard_map parity tests (even /
uneven global lengths, causal / non-causal, gradients, serving prefill) run
on 8 virtual devices through the ``subproc`` fixture."""

from __future__ import annotations

import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa
from repro.kernels.ring_attention import kernel as rk


def _qkv(key, B, S, H, Hk, D):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, H, S, D))      # head-major (kernel layout)
    k = jax.random.normal(k2, (B, Hk, S, D))
    v = jax.random.normal(k3, (B, Hk, S, D))
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("Hk", [4, 2])
def test_ring_step_kernel_matches_jnp_twin(causal, Hk):
    B, S, H, D = 1, 64, 4, 16
    q, k, v = _qkv(jax.random.PRNGKey(0), B, S, H, Hk, D)
    # a mid-schedule carry (not the initial one): m finite, l/acc nonzero
    m = jax.random.normal(jax.random.PRNGKey(1), (B, H, S, 1)) * 0.5
    l = jax.random.uniform(jax.random.PRNGKey(2), (B, H, S, 1)) + 1.0
    acc = jax.random.normal(jax.random.PRNGKey(3), (B, H, S, D))
    kw = dict(
        q_offset=jnp.int32(64), k_offset=jnp.int32(32), kv_len=jnp.int32(50),
        scale=0.25, causal=causal,
    )
    out_k = rk.ring_step_fwd(q, k, v, m, l, acc, block_q=32, block_k=32, **kw)
    out_r = rk.ring_step_ref(q, k, v, m, l, acc, **kw)
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5)


def test_ring_step_skips_fully_masked_tiles_consistently():
    """Tiles entirely beyond kv_len or entirely in the causal future must be
    skipped without perturbing the carry (the tile-skip predicate and the
    in-tile mask must agree)."""

    B, S, H, D = 1, 64, 2, 16
    q, k, v = _qkv(jax.random.PRNGKey(4), B, S, H, H, D)
    m = jnp.full((B, H, S, 1), rk.NEG_INF)
    l = jnp.zeros((B, H, S, 1))
    acc = jnp.zeros((B, H, S, D))
    # KV block strictly in the future of every Q row: carry must be unchanged
    kw = dict(q_offset=jnp.int32(0), k_offset=jnp.int32(512),
              kv_len=jnp.int32(64), scale=0.25, causal=True)
    m2, l2, acc2 = rk.ring_step_fwd(q, k, v, m, l, acc, block_q=32, block_k=32, **kw)
    np.testing.assert_array_equal(np.asarray(m2), np.asarray(m))
    np.testing.assert_array_equal(np.asarray(l2), np.asarray(l))
    np.testing.assert_array_equal(np.asarray(acc2), np.asarray(acc))
    # kv_len == 0 (a fully padded shard): same invariant, non-causal
    kw = dict(q_offset=jnp.int32(0), k_offset=jnp.int32(0),
              kv_len=jnp.int32(0), scale=0.25, causal=False)
    m2, l2, acc2 = rk.ring_step_fwd(q, k, v, m, l, acc, block_q=32, block_k=32, **kw)
    np.testing.assert_array_equal(np.asarray(l2), np.asarray(l))


@pytest.mark.parametrize("causal", [True, False])
def test_degenerate_ring_of_one_matches_flash(causal):
    """n=1 periodic ring (a single-device mesh): zero permutes, one step —
    must equal the dense flash reference exactly."""

    from repro.core import _compat, topology
    from repro.kernels.ring_attention import ops as ring_ops

    mesh = _compat.make_mesh((1,), ("ring",))
    cart = topology.CartComm(
        mesh, ("ring",), dims=(1,), periods=(True,), managed=False, tag="r1"
    )
    key = jax.random.PRNGKey(5)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (2, 48, 4, 16))
    k = jax.random.normal(k2, (2, 48, 2, 16))
    v = jax.random.normal(k3, (2, 48, 2, 16))
    with mesh:
        out = ring_ops.ring_attention(
            cart, q, k, v, causal=causal, impl="pallas", block_q=32, block_k=32
        )
    ref = fa.flash_attention(q, k, v, causal=causal, impl="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_ring_attention_rejects_non_periodic_ring():
    from repro.core import _compat, errors, topology
    from repro.kernels.ring_attention import ops as ring_ops

    mesh = _compat.make_mesh((1,), ("ring",))
    cart = topology.CartComm(
        mesh, ("ring",), dims=(1,), periods=(False,), managed=False, tag="r0"
    )
    x = jnp.zeros((1, 8, 2, 4))
    with pytest.raises(errors.TopologyError):
        ring_ops.ring_attention(cart, x, x, x)


RING_PARITY = textwrap.dedent("""
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core import _compat, topology
    from repro.kernels.flash_attention import ops as fa
    from repro.kernels.ring_attention import ops as ring_ops

    N = 8
    mesh = _compat.make_mesh((N,), ("ring",))
    cart = topology.CartComm(mesh, ("ring",), dims=(N,), periods=(True,),
                             managed=False, tag="ring-test")
    spec = P(None, "ring", None, None)

    def ring(q, k, v, *, causal, impl, global_len):
        def body(ql, kl, vl):
            return ring_ops.ring_attention(
                cart, ql, kl, vl, causal=causal, global_len=global_len,
                impl=impl, block_q=16, block_k=16)
        return _compat.shard_map(
            body, mesh=mesh, in_specs=(spec, spec, spec), out_specs=spec)(q, k, v)

    def check(S, causal, impl, tol=5e-5):
        ks = jax.random.split(jax.random.PRNGKey(S), 3)
        q = jax.random.normal(ks[0], (2, S, 4, 16))
        k = jax.random.normal(ks[1], (2, S, 2, 16))
        v = jax.random.normal(ks[2], (2, S, 2, 16))
        pad = (-S) % N
        qp = jnp.pad(q, ((0,0),(0,pad),(0,0),(0,0)))
        kp = jnp.pad(k, ((0,0),(0,pad),(0,0),(0,0)))
        vp = jnp.pad(v, ((0,0),(0,pad),(0,0),(0,0)))
        with mesh:
            out = jax.jit(lambda a, b, c: ring(
                a, b, c, causal=causal, impl=impl, global_len=S))(qp, kp, vp)
        ref = fa.flash_attention(q, k, v, causal=causal, impl="ref")
        np.testing.assert_allclose(np.asarray(out)[:, :S], np.asarray(ref),
                                   atol=tol, rtol=tol)
        print("ok", S, causal, impl)

    for impl in ("ref", "pallas"):
        check(128, True, impl)        # even shards
        check(128, False, impl)
        check(101, True, impl)        # ragged tail: shard 6 partial, 7 empty
        check(101, False, impl)

    # gradient parity through the custom-VJP ring vs the dense reference
    S = 96
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (1, S, 2, 16))
    k = jax.random.normal(ks[1], (1, S, 2, 16))
    v = jax.random.normal(ks[2], (1, S, 2, 16))
    with mesh:
        g_ring = jax.jit(jax.grad(
            lambda a, b, c: ring(a, b, c, causal=True, impl="pallas",
                                 global_len=S).sum(), argnums=(0, 1, 2)
        ))(q, k, v)
    g_ref = jax.grad(
        lambda a, b, c: fa.flash_attention(a, b, c, causal=True,
                                           impl="ref").sum(), argnums=(0, 1, 2)
    )(q, k, v)
    for a, b in zip(g_ring, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4)
    print("RING_PARITY_OK")
""")


SERVER_RING = textwrap.dedent("""
    import dataclasses
    import numpy as np
    from repro.configs.base import ModelConfig, ParallelConfig
    from repro.core._compat import make_mesh
    from repro.runtime.server import Request, Server, ServerConfig

    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
                      vocab_size=256, dtype="float32")
    scfg = ServerConfig(max_batch=2, max_new_tokens=4)
    prompts = [np.arange(1, 33, dtype=np.int32), np.arange(5, 29, dtype=np.int32)]

    base = Server(cfg, ParallelConfig(), scfg, mesh)
    t0, _ = base.generate([Request(tokens=p.copy()) for p in prompts])
    ring = Server(cfg, dataclasses.replace(ParallelConfig(), ring_attention=True),
                  scfg, mesh)
    t1, _ = ring.generate([Request(tokens=p.copy()) for p in prompts])
    np.testing.assert_array_equal(t0, t1)
    print("SERVER_RING_OK")
""")


TRAINER_RING = textwrap.dedent("""
    from repro.configs.base import ModelConfig, ParallelConfig
    from repro.core._compat import make_mesh
    from repro.runtime.trainer import Trainer, TrainerConfig

    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
                      vocab_size=256, dtype="float32")
    tcfg = TrainerConfig(steps=3, log_every=1, ring_attention=4)
    mesh = make_mesh((8,), ("data",))
    trainer = Trainer(cfg, ParallelConfig(), tcfg, mesh,
                      seq_len=96, global_batch=8)
    assert trainer.mesh.shape == {"data": 2, "model": 4}, trainer.mesh.shape
    assert trainer.pcfg.ring_attention
    result = trainer.run()
    assert result["final_step"] == 3
    losses = [m["loss"] for m in result["metrics"]]
    assert all(l == l and l < 100 for l in losses), losses
    print("TRAINER_RING_OK")
""")


def test_ring_parity_under_shard_map(subproc):
    assert "RING_PARITY_OK" in subproc(RING_PARITY, n=8)


def test_server_ring_prefill_matches_dense(subproc):
    assert "SERVER_RING_OK" in subproc(SERVER_RING, n=8, timeout=1200)


def test_trainer_ring_attention_mode(subproc):
    assert "TRAINER_RING_OK" in subproc(TRAINER_RING, n=8, timeout=1200)
