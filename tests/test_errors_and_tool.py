"""Error handling (paper C5: opt-in trace-time checking, typed exceptions
with error classes) and the tool interface (cvars/pvars)."""

from __future__ import annotations

import jax.numpy as jnp
import pytest

from repro import core as mpx
from repro.core import errors, tool


def test_error_classes_and_codes():
    exc = None
    try:
        errors.fail(errors.ErrorClass.ERR_RANK, "bad rank")
    except errors.RankError as e:
        exc = e
    assert exc is not None
    assert exc.klass is errors.ErrorClass.ERR_RANK
    assert "bad rank" in str(exc)


def test_error_checking_toggle():
    comm = mpx.world()
    mpx.set_error_checking(False)
    try:
        # out-of-range root passes unchecked (the compile-time macro off)
        fn = comm.spmd(lambda: mpx.broadcast(comm, jnp.float32(1.0), root=0))
        fn()
    finally:
        mpx.set_error_checking(True)
    with pytest.raises(errors.RootError):
        comm.spmd(lambda: mpx.broadcast(comm, jnp.float32(1.0), root=99))()


def test_invalid_root_raises():
    comm = mpx.world()
    with pytest.raises(errors.RootError):
        comm.run(lambda: mpx.broadcast(comm, jnp.float32(0.0), root=-1))


def test_copy_is_deleted():
    import copy

    comm = mpx.world()
    with pytest.raises(errors.CommError):
        copy.copy(comm)
    dup = comm.dup()
    assert dup.size() == comm.size()


def test_cvars_registry():
    assert "error_checking" in tool.cvar_list()
    tool.cvar_set("error_checking", False)
    assert tool.cvar_get("error_checking") is False
    tool.cvar_set("error_checking", True)
    with pytest.raises(errors.TypeError_):
        tool.cvar_set("error_checking", "yes")
    with pytest.raises(errors.ArgError):
        tool.cvar_set("nonexistent", 1)


def test_pvar_counters():
    tool.pvar_reset()
    comm = mpx.world()
    comm.run(lambda: comm.allreduce(jnp.float32(1.0)))  # method facade counts
    counts = tool.pvar_read()
    assert counts.get("allreduce", 0) >= 1


def test_hlo_collective_parse_smoke():
    stats = tool.parse_hlo_collectives(
        '%ag = f32[16,32]{1,0} all-gather(%p0), dimensions={0}, '
        'replica_groups={{0,1,2,3}}\n'
        '%p0 = f32[4,32]{1,0} parameter(0)\n'
    )
    assert stats.count["all-gather"] == 1
    assert stats.result_bytes["all-gather"] == 16 * 32 * 4
