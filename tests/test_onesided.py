"""RMA window subsystem (paper C1 — MPI 4.0 chapter 12).

Epoch discipline and argument validation run in-process (they raise at
trace/issue time, before any collective lowers); numerics — put/get across
patterns, the full accumulate op set, atomics, pytree windows, paged and
request-based transfers, and the disaggregated serving transport — run on an
8-virtual-device world in a subprocess."""

from __future__ import annotations

import textwrap

import jax.numpy as jnp
import pytest

from repro import core as mpx
from repro.core import errors, onesided
from repro.core.descriptors import ReduceOp, WindowSpec


# -- epoch / validation (trace-time, single device) ---------------------------


def test_access_outside_epoch_is_err_win():
    win = onesided.Window(mpx.world(), jnp.zeros((4,), jnp.float32))
    with pytest.raises(errors.WinError):
        win.put(jnp.ones((4,), jnp.float32), [(0, 0)])
    with pytest.raises(errors.WinError):
        win.get([(0, 0)])
    with pytest.raises(errors.WinError):
        win.accumulate(jnp.ones((4,), jnp.float32), target=0)
    with pytest.raises(errors.WinError):
        win.rput(jnp.ones((4,), jnp.float32), [(0, 0)])


def test_duplicate_put_targets_are_err_rank():
    # two origins writing one target in an epoch is a data race, never
    # last-writer-wins (mirrors send_recv's duplicate-source check)
    win = onesided.Window(mpx.world(), jnp.zeros((4,), jnp.float32)).fence()
    with pytest.raises(errors.RankError):
        win.put(jnp.ones((4,), jnp.float32), [(0, 1), (2, 1)])
    with pytest.raises(errors.RankError):
        win.rput(jnp.ones((4,), jnp.float32), [(0, 1), (2, 1)])


def test_epoch_write_ledger_spans_calls():
    """The duplicate-target invariant holds per EPOCH, not per call: a
    second put covering an already-written span of the same target raises
    ERR_RANK even from a separate call (rput is lazy, so this validates at
    issue time without tracing)."""

    win = onesided.Window(mpx.world(), jnp.zeros((8,), jnp.float32)).fence()
    win.rput(jnp.ones((8,), jnp.float32), [(0, 0)], page=(0, 2))
    win.rput(jnp.ones((8,), jnp.float32), [(0, 0)], page=(1, 2))  # disjoint: ok
    with pytest.raises(errors.RankError):
        win.rput(jnp.ones((8,), jnp.float32), [(0, 0)])           # overlaps both
    with pytest.raises(errors.RankError):
        win.rput(jnp.ones((8,), jnp.float32), [(0, 0)], page=(1, 4))  # inside page 1/2


def test_perm_out_of_range_is_err_rank():
    win = onesided.Window(mpx.world(), jnp.zeros((4,), jnp.float32)).fence()
    n = mpx.world().size()
    with pytest.raises(errors.RankError):
        win.put(jnp.ones((4,), jnp.float32), [(0, n)])
    with pytest.raises(errors.RankError):
        win.accumulate(jnp.ones((4,), jnp.float32), target=n)


def test_page_out_of_range_is_err_count_at_issue():
    # validated when the request is issued (rput is lazy: without this, a
    # bad index would surface as a raw IndexError at force time)
    win = onesided.Window(mpx.world(), jnp.zeros((8,), jnp.float32)).fence()
    with pytest.raises(errors.CountError):
        win.rput(jnp.ones((8,), jnp.float32), [(0, 0)], page=(5, 2))
    with pytest.raises(errors.CountError):
        win.put(jnp.ones((8,), jnp.float32), [(0, 0)], page=(2, 2))


def test_bare_none_window_is_err_type():
    # None is compliant only as an aggregate member; a bare None operand
    # must not become a zero-extent no-op window
    with pytest.raises(errors.TypeError_):
        onesided.Window(mpx.world(), None)


def test_window_spec_honored():
    # passive-target locks cannot be emulated: asking for them is refused
    with pytest.raises(errors.UnsupportedError):
        onesided.Window(mpx.world(), jnp.zeros(4), WindowSpec(no_locks=False))
    # loc ops have no two-operand combine
    win = onesided.Window(mpx.world(), jnp.zeros((4,), jnp.float32)).fence()
    with pytest.raises(errors.OpError):
        win.accumulate(jnp.ones((4,), jnp.float32), target=0, op=ReduceOp.MAXLOC)
    # NO_OP only makes sense where there is a fetch
    with pytest.raises(errors.OpError):
        win.accumulate(jnp.ones((4,), jnp.float32), target=0, op=ReduceOp.NO_OP)


def test_shape_mismatch_is_err_truncate():
    win = onesided.Window(mpx.world(), jnp.zeros((4,), jnp.float32)).fence()
    with pytest.raises(errors.TruncateError):
        win.put(jnp.ones((5,), jnp.float32), [(0, 0)])


def test_extent_and_datatype():
    win = onesided.Window(mpx.world(), jnp.zeros((4,), jnp.float32))
    assert win.extent() == 16
    assert win.datatype is None
    agg = {"a": jnp.zeros((2,), jnp.float32), "b": jnp.zeros((3,), jnp.int32)}
    win = onesided.Window(mpx.world(), agg)
    assert win.extent() == 2 * 4 + 3 * 4
    assert win.datatype is not None


# -- numerics on 8 virtual ranks ----------------------------------------------


CODE_RMA = textwrap.dedent("""
    import dataclasses
    import jax, jax.numpy as jnp
    import numpy as np
    from repro import core as mpx
    from repro.core import futures, onesided
    from repro.core.descriptors import ReduceOp, WindowSpec

    comm = mpx.world()
    N = comm.size()
    assert N == 8

    # --- put / get / accumulate over the op set -----------------------------
    @comm.spmd
    def ops():
        win = onesided.Window(comm, jnp.full((4,), comm.rank() + 1, jnp.float32))
        win.fence()
        got = win.get([((d - 1) % N, d) for d in range(N)])       # ring read
        win.put(jnp.full((4,), 99.0, jnp.float32), [(1, 0)])
        win.accumulate(jnp.full((4,), comm.rank() + 1, jnp.float32),
                       target=2, op=ReduceOp.MAX)
        win.accumulate(jnp.full((4,), 2.0, jnp.float32),
                       target=3, op=ReduceOp.PROD)
        win.fence()
        b = win.buffer
        return (got,
                mpx.broadcast(comm, b, root=0),
                mpx.broadcast(comm, b, root=2),
                mpx.broadcast(comm, b, root=3))

    got, b0, b2, b3 = ops()
    np.testing.assert_array_equal(np.asarray(got), np.full(4, float(N)))
    np.testing.assert_array_equal(np.asarray(b0), np.full(4, 99.0))
    # rank 2 window: max(own 3, contributions 1..8) = 8
    np.testing.assert_array_equal(np.asarray(b2), np.full(4, 8.0))
    # rank 3 window: 4 * prod(2^8) = 4 * 256
    np.testing.assert_array_equal(np.asarray(b3), np.full(4, 4.0 * 2.0 ** N))
    print("OPS_OK")

    # --- WindowSpec default accumulate op ------------------------------------
    @comm.spmd
    def spec_default():
        win = onesided.Window(comm, jnp.full((2,), comm.rank(), jnp.float32),
                              WindowSpec(accumulate_op=ReduceOp.MIN))
        win.fence()
        win.accumulate(jnp.full((2,), comm.rank(), jnp.float32), target=5)
        win.fence()
        return mpx.broadcast(comm, win.buffer, root=5)

    np.testing.assert_array_equal(np.asarray(spec_default()), np.zeros(2))
    print("SPEC_OK")

    # --- atomics -------------------------------------------------------------
    @comm.spmd
    def atomics():
        win = onesided.Window(comm, jnp.full((4,), comm.rank(), jnp.float32))
        win.fence()
        old_fo = win.fetch_and_op(jnp.float32(5.0), target=1,
                                  op=ReduceOp.SUM, index=2)
        old_cas = win.compare_and_swap(2.0, 42.0, target=2, index=0)
        old_miss = win.compare_and_swap(7.0, -1.0, target=2, index=1)
        ga = win.get_accumulate(jnp.ones((4,), jnp.float32), target=4,
                                op=ReduceOp.NO_OP)
        win.fence()
        b = win.buffer
        return (old_fo, old_cas, old_miss, ga,
                mpx.broadcast(comm, b, root=1), mpx.broadcast(comm, b, root=2),
                mpx.broadcast(comm, b, root=4))

    old_fo, old_cas, old_miss, ga, b1, b2, b4 = atomics()
    assert float(old_fo) == 1.0
    assert float(old_cas) == 2.0 and float(old_miss) == 2.0
    np.testing.assert_array_equal(np.asarray(ga), np.full(4, 4.0))
    np.testing.assert_array_equal(np.asarray(b1), np.array([1., 1., 41., 1.]))
    np.testing.assert_array_equal(np.asarray(b2), np.array([42., 2., 2., 2.]))
    np.testing.assert_array_equal(np.asarray(b4), np.full(4, 4.0))  # NO_OP left it
    print("ATOMICS_OK")

    # --- pytree window: pack/unpack round-trip via paged rput ----------------
    @mpx.register_aggregate
    @dataclasses.dataclass
    class KV:
        k: jax.Array
        v: jax.Array

    @comm.spmd
    def pytree():
        agg = KV(k=jnp.full((2, 3), comm.rank(), jnp.float32),
                 v=jnp.full((4,), comm.rank(), jnp.int32))
        win = onesided.Window(comm, jax.tree_util.tree_map(jnp.zeros_like, agg),
                              WindowSpec(num_pages=3))
        win.fence()
        # bare page index: spec.num_pages is the divisor (honored field)
        futs = [win.rput(agg, [(5, 1)], page=p) for p in range(3)]
        futures.when_all(futs).get()      # trace-level Waitall dispatch
        win.fence()
        out = win.buffer
        return mpx.broadcast(comm, out.k, root=1), mpx.broadcast(comm, out.v, root=1)

    k, v = pytree()
    np.testing.assert_array_equal(np.asarray(k), np.full((2, 3), 5.0, np.float32))
    np.testing.assert_array_equal(np.asarray(v), np.full((4,), 5, np.int32))
    print("PYTREE_OK")

    # --- rput/raccumulate -> then ordering: chains apply in issue order -----
    # REPLACE-then-SUM is order-observable: issue order gives 5 + N*1 = 13;
    # the reverse would give 5.  (Two puts to one location in an epoch is the
    # race the write ledger rejects, so ordering is shown through accumulate.)
    @comm.spmd
    def ordering():
        win = onesided.Window(comm, jnp.zeros((4,), jnp.float32))
        win.fence()
        f1 = win.raccumulate(jnp.full((4,), 5.0, jnp.float32),
                             target=6, op=ReduceOp.REPLACE)
        f2 = f1.then(lambda f: (
            f.get(),
            win.raccumulate(jnp.ones((4,), jnp.float32),
                            target=6, op=ReduceOp.SUM).get(),
        )[1])
        futures.when_all([f1, f2]).get()   # then-derived futures are caller-owned
        win.fence()
        return mpx.broadcast(comm, win.buffer, root=6)

    np.testing.assert_array_equal(np.asarray(ordering()), np.full(4, 5.0 + N))
    print("ORDER_OK")

    # --- REPLACE moves data across ranks (lowest-ranked origin wins) --------
    @comm.spmd
    def replace_moves():
        win = onesided.Window(comm, jnp.zeros((2,), jnp.float32))
        win.fence()
        win.accumulate(jnp.full((2,), comm.rank() + 10, jnp.float32),
                       target=3, op=ReduceOp.REPLACE)
        win.fence()
        return mpx.broadcast(comm, win.buffer, root=3)

    np.testing.assert_array_equal(np.asarray(replace_moves()), np.full(2, 10.0))
    print("REPLACE_OK")

    # --- per-epoch write ledger: disjoint pages fine, overlap is ERR_RANK ---
    @comm.spmd
    def epoch_ledger():
        win = onesided.Window(comm, jnp.zeros((8,), jnp.float32))
        win.fence()
        win.put(jnp.full((8,), 1.0, jnp.float32), [(0, 7)], page=(0, 2))
        win.put(jnp.full((8,), 2.0, jnp.float32), [(1, 7)], page=(1, 2))
        try:
            win.put(jnp.full((8,), 3.0, jnp.float32), [(2, 7)])  # full window
            raise AssertionError("expected ERR_RANK")
        except mpx.errors.RankError:
            pass
        win.fence()
        win.fence()   # fresh epoch: the ledger is cleared
        win.put(jnp.full((8,), 4.0, jnp.float32), [(2, 7)])
        win.fence()
        return mpx.broadcast(comm, win.buffer, root=7)

    np.testing.assert_array_equal(np.asarray(epoch_ledger()), np.full(8, 4.0))
    print("LEDGER_OK")

    # --- unified mask shape: empty perm is a well-formed no-op ---------------
    @comm.spmd
    def empty_perm():
        win = onesided.Window(comm, jnp.full((4,), comm.rank(), jnp.float32))
        win.fence()
        win.put(jnp.full((4,), 7.0, jnp.float32), [])
        win.fence()
        return mpx.broadcast(comm, win.buffer, root=3)

    np.testing.assert_array_equal(np.asarray(empty_perm()), np.full(4, 3.0))
    print("EMPTY_OK")
""")


def test_rma_numerics_8dev(subproc):
    out = subproc(CODE_RMA, n=8)
    for marker in ("OPS_OK", "SPEC_OK", "ATOMICS_OK", "PYTREE_OK",
                   "ORDER_OK", "REPLACE_OK", "LEDGER_OK", "EMPTY_OK"):
        assert marker in out


# -- the disaggregated serving transport --------------------------------------


CODE_DISAGG = textwrap.dedent("""
    import numpy as np
    from repro.configs.base import ModelConfig, ParallelConfig
    from repro.launch.mesh import make_host_communicator
    from repro.runtime.server import (
        DisaggregatedServer, Request, Server, ServerConfig)
    from repro.core import tool

    # float32: the transport is bit-exact in any dtype; pinning the compute
    # dtype isolates it from partitioning-dependent bf16 rounding
    cfg = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=32,
                      num_heads=2, num_kv_heads=1, head_dim=16, d_ff=64,
                      vocab_size=64, dtype="float32")
    scfg = ServerConfig(max_batch=2, max_new_tokens=6, temperature=0.0)
    rng = np.random.default_rng(0)
    reqs = [Request(tokens=rng.integers(1, cfg.vocab_size, size=(8,),
                                        dtype=np.int32))
            for _ in range(2)]

    base = Server(cfg, ParallelConfig(), scfg, make_host_communicator())
    tok_base, _ = base.generate(reqs)

    dis = DisaggregatedServer(cfg, ParallelConfig(), scfg, kv_pages=3)
    assert dis.prefill.comm.group().intersection(dis.decode.comm.group()).size() == 0
    tok_dis, stats = dis.generate(reqs)
    assert np.array_equal(tok_base, tok_dis), (tok_base, tok_dis)
    assert stats["kv_bytes"] > 0 and stats["kv_pages"] == 3

    # the handoff is persistent: a second generate re-fires, never re-traces
    tok2, _ = dis.generate(reqs)
    assert np.array_equal(tok2, tok_base)
    assert tool.pvar_read()["trace:kv_transfer"] == 1
    assert tool.pvar_read()["rma_rput"] == 3
    print("DISAGG_OK")
""")


def test_disaggregated_serving_parity_8dev(subproc):
    """Prefill and decode on disjoint groups of one session pset; KV blocks
    cross via window rput; tokens match the single-group baseline
    token-for-token at temperature 0."""

    out = subproc(CODE_DISAGG, n=8)
    assert "DISAGG_OK" in out
