"""Numerical validation of the distributed execution paths the §Perf cells
compile: ring attention (training SP), the seq-sharded + merged decode
(cell D config), and ragged collectives — on an 8-virtual-device mesh."""

from __future__ import annotations

import textwrap


RING_ATTENTION = textwrap.dedent("""
    import dataclasses
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs.base import ModelConfig, ParallelConfig
    from repro.models import attention as attn
    from repro.models import common

    from repro.core._compat import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = ModelConfig(name="t", family="dense", num_layers=1, d_model=64,
                      num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
                      vocab_size=256, dtype="float32")
    p = attn.init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 64))
    positions = jnp.arange(32)

    base_pc = ParallelConfig()
    ring_pc = ParallelConfig(ring_attention=True)

    ref = attn.attention_full(p, x, cfg, base_pc, positions=positions,
                              sliding_window=None, mesh=None)
    with mesh:
        ring = jax.jit(lambda xx: attn.attention_full(
            p, xx, cfg, ring_pc, positions=positions, sliding_window=None,
            mesh=mesh))(x)
    np.testing.assert_allclose(np.asarray(ring), np.asarray(ref),
                               atol=2e-4, rtol=2e-4)
    print("RING_ATTENTION_OK")
""")


SHARDED_DECODE = textwrap.dedent("""
    import dataclasses
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import base
    from repro.models import api

    from repro.core._compat import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = dataclasses.replace(base.get_smoke_config("phi4_mini_3_8b"),
                              dtype="float32")
    # the cell-D configuration: sequence-sharded cache + exact merge (+int8)
    pc_ref = base.get_parallel("phi4_mini_3_8b")
    pc_opt = dataclasses.replace(
        pc_ref, seq_shard_cache=True, flash_decode_merge=True)
    pc_q8 = dataclasses.replace(pc_opt, kv_cache_dtype="int8")

    bundle = api.build(cfg)
    params = bundle.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(3)
    B, S = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)), jnp.int32)

    _, cache = bundle.prefill(params, {"tokens": toks[:, :S]}, pc_ref, None,
                              extra_capacity=8)
    ref_logits, _ = bundle.decode(params, cache, toks[:, S:S+1], pc_ref, None)

    for name, pc, tol in (("merge", pc_opt, 2e-3), ("int8", pc_q8, 0.35)):
        _, c2 = bundle.prefill(params, {"tokens": toks[:, :S]}, pc, None,
                               extra_capacity=8)
        with mesh:
            out, _ = jax.jit(
                lambda p_, c_, t_: bundle.decode(p_, c_, t_, pc, mesh)
            )(params, c2, toks[:, S:S+1])
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref_logits), atol=tol, rtol=tol,
            err_msg=name)
    print("SHARDED_DECODE_OK")
""")


RAGGED_COLLECTIVES = textwrap.dedent("""
    import jax, jax.numpy as jnp
    import numpy as np
    from repro import core as mpx

    comm = mpx.world()
    N = comm.size()

    # allgatherv: per-rank counts differ; result is the ragged concatenation
    counts = [1 + (i % 3) for i in range(N)]

    @comm.spmd
    def agv():
        c = max(counts)
        data = jnp.full((c,), comm.rank() + 1, jnp.float32)
        return comm.allgatherv(data, counts)
    out = np.asarray(agv())
    expect = np.concatenate([np.full(c, i + 1.0) for i, c in enumerate(counts)])
    np.testing.assert_array_equal(out, expect)

    # alltoallv: symmetric counts, padded blocks of max(counts) per peer
    @comm.spmd
    def a2av():
        block = jnp.full((N * 2,), comm.rank(), jnp.float32)
        out, _ = comm.alltoallv(block, [2] * N)
        return out
    out = np.asarray(a2av())
    np.testing.assert_array_equal(out[::2], np.arange(N, dtype=np.float32))
    print("RAGGED_OK")
""")


def test_ring_attention_matches_full(subproc):
    assert "RING_ATTENTION_OK" in subproc(RING_ATTENTION, n=8)


def test_seq_sharded_merged_decode_matches_reference(subproc):
    assert "SHARDED_DECODE_OK" in subproc(SHARDED_DECODE, n=8, timeout=1200)


def test_ragged_collectives(subproc):
    assert "RAGGED_OK" in subproc(RAGGED_COLLECTIVES, n=8)
