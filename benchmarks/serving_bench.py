"""Open-loop serving benchmark: continuous batching vs fixed batches.

An *open-loop* (Poisson) arrival process — requests arrive on their own
schedule whether or not the server is ready, the load model closed-loop
benchmarks famously get wrong — drives the same request trace through

* the **fixed-batch** :class:`~repro.runtime.server.Server`: requests are
  grouped into ``max_batch`` batches in arrival order; a batch prefills when
  its *last* member has arrived and holds every slot for the full
  ``max_new_tokens`` decode budget (head-of-line blocking on both ends);
* the **continuous-batching** :class:`~repro.runtime.engine.Engine`:
  requests join the running decode iteration as slots free up and retire at
  their *own* ``max_new`` budget.

Arrivals and TTFT are measured in **virtual decode steps** (one engine
iteration = one unit), which makes the comparison deterministic for a
seeded trace: the fixed server's cost model is exactly ``1 + (max_new - 1)``
steps per batch starting when its last member arrived, the engine's is its
actual step count.  Throughput is measured in real wall-clock over the same
trace (both paths generate the *same* useful tokens at temperature 0, so
tokens/s differences are pure scheduling).

Writes ``artifacts/bench/serving_bench.json`` with the two tracked ratios:

* ``tokens_ratio``    — continuous / fixed useful-tokens-per-second (> 1:
  continuous wins);
* ``ttft_p99_ratio``  — continuous / fixed p99 time-to-first-token in
  virtual steps (< 1: continuous wins).

    PYTHONPATH=src python -m benchmarks.serving_bench [--quick]
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
OUT = ROOT / "artifacts" / "bench"


def _percentile(xs, q):
    xs = sorted(xs)
    if not xs:
        return 0.0
    i = min(len(xs) - 1, max(0, int(round(q * (len(xs) - 1)))))
    return float(xs[i])


def make_trace(n, bucket, max_new, seed=0):
    """Seeded open-loop trace: Poisson arrivals (exponential inter-arrival
    in virtual steps), ragged prompt lengths, heterogeneous per-request
    generation budgets (the head-of-line driver)."""

    import numpy as np

    rng = np.random.default_rng(seed)
    t = 0.0
    trace = []
    for _ in range(n):
        # open-loop rate above the service rate: the queue builds, slots stay
        # saturated, and the comparison measures scheduling rather than idle
        t += rng.exponential(0.5)
        # generation budgets spread over the full range: the length variance
        # real serving traces show, and exactly what fixed batching pads away
        trace.append({
            "arrival_step": int(t),
            "tokens": rng.integers(1, 64, size=(int(rng.integers(2, bucket + 1)),)).astype(np.int32),
            "max_new": int(rng.integers(2, max_new + 1)),
        })
    return trace


def run_continuous(srv, ecfg, trace):
    from repro.runtime.engine import Engine

    eng = Engine(srv, ecfg)
    pending = list(trace)
    handles = {}
    step = 0
    t0 = time.perf_counter()
    while pending or eng.waiting or any(r is not None for r in eng.active):
        while pending and pending[0]["arrival_step"] <= step:
            spec = pending.pop(0)
            h = eng.submit(spec["tokens"], max_new=spec["max_new"])
            handles[h.rid] = (spec, h, {"first_step": None})
        before = {rid: len(hb[1].generated) for rid, hb in handles.items()}
        eng.step()
        for rid, (spec, h, meta) in handles.items():
            if meta["first_step"] is None and len(h.generated) > before.get(rid, 0):
                meta["first_step"] = step + 1     # token exists after this step
        step += 1
    wall = time.perf_counter() - t0

    useful = sum(len(h.generated) for _, h, _ in handles.values())
    ttfts = [
        meta["first_step"] - spec["arrival_step"]
        for spec, _h, meta in handles.values()
    ]
    return {
        "wall_s": wall,
        "virtual_steps": step,
        "useful_tokens": useful,
        "tokens_per_s": useful / max(wall, 1e-9),
        "ttft_p50_steps": _percentile(ttfts, 0.50),
        "ttft_p99_steps": _percentile(ttfts, 0.99),
        "preemptions": eng.stats()["preemptions"],
    }, {rid: list(h.generated) for rid, (_s, h, _m) in handles.items()}


def run_fixed(srv, trace, bucket):
    """Fixed batches in arrival order.  Virtual cost model: a batch starts
    at max(last member's arrival, previous batch's end), spends one step on
    prefill (first token) and ``max_new - 1`` decode steps; wall-clock is
    the sum of the real ``generate`` calls."""

    import numpy as np

    from repro.runtime.server import Request

    scfg = srv.scfg
    batches = [trace[i:i + scfg.max_batch] for i in range(0, len(trace), scfg.max_batch)]
    wall = 0.0
    end = 0
    useful = 0
    ttfts = []
    outputs = []
    for group in batches:
        start = max(end, max(s["arrival_step"] for s in group))
        # left-pad every prompt to the bucket the engine uses, so both paths
        # prefill byte-identical content and the parity check is meaningful
        padded = [
            Request(tokens=np.concatenate([
                np.zeros((bucket - len(s["tokens"]),), np.int32), s["tokens"]
            ]))
            for s in group
        ]
        t0 = time.perf_counter()
        toks, _stats = srv.generate(padded)
        wall += time.perf_counter() - t0
        end = start + scfg.max_new_tokens
        for row, s in enumerate(group):
            ttfts.append(start + 1 - s["arrival_step"])
            useful += s["max_new"]               # tokens past the budget are pad
            outputs.append(np.asarray(toks[row][: s["max_new"]]))
    return {
        "wall_s": wall,
        "virtual_steps": end,
        "useful_tokens": useful,
        "tokens_per_s": useful / max(wall, 1e-9),
        "ttft_p50_steps": _percentile(ttfts, 0.50),
        "ttft_p99_steps": _percentile(ttfts, 0.99),
    }, outputs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    sys.path.insert(0, str(ROOT / "src"))  # when PYTHONPATH was not exported

    import numpy as np

    from repro.configs.base import ModelConfig, ParallelConfig
    from repro.launch.mesh import make_host_communicator
    from repro.runtime.engine import EngineConfig
    from repro.runtime.server import Server, ServerConfig

    n = args.requests or (8 if args.quick else 16)
    bucket, max_new = 8, 24
    # float32: near-tied argmaxes under bf16 rounding would make the parity
    # check (same useful tokens on both paths) flaky
    cfg = ModelConfig(
        name="bench-serve", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128,
        dtype="float32",
    )
    scfg = ServerConfig(max_batch=4, max_new_tokens=max_new, temperature=0.0)
    srv = Server(cfg, ParallelConfig(), scfg, make_host_communicator())
    trace = make_trace(n, bucket, max_new, seed=args.seed)

    # warm pass: compile every persistent step (prefill buckets, the two
    # decode signatures, insert-row) so the measured pass times scheduling,
    # not tracing — the request caches live on the server and persist
    ecfg = EngineConfig(prompt_bucket=bucket, block_tokens=4)
    warm = make_trace(min(n, 2 * scfg.max_batch), bucket, max_new, seed=args.seed + 1)
    run_continuous(srv, ecfg, warm)
    run_fixed(srv, warm, bucket)

    cont, cont_out = run_continuous(srv, ecfg, trace)
    fixed, fixed_out = run_fixed(srv, trace, bucket)

    # same trace, same model, temperature 0: the engine's tokens must prefix-
    # match the fixed server's (the bench is invalid if scheduling changed
    # the outputs — pad the fixed batch so every prompt shares the bucket)
    parity = all(
        (np.asarray(cont_out[i])[: len(f)] == f[: len(cont_out[i])]).all()
        for i, f in enumerate(fixed_out)
    )

    result = {
        "requests": n,
        "parity_prefix": bool(parity),
        "continuous": cont,
        "fixed": fixed,
        "tokens_ratio": cont["tokens_per_s"] / max(fixed["tokens_per_s"], 1e-9),
        "ttft_p99_ratio": cont["ttft_p99_steps"] / max(fixed["ttft_p99_steps"], 1e-9),
    }
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "serving_bench.json").write_text(json.dumps(result, indent=1))

    print("| path | tokens/s | p50 TTFT (steps) | p99 TTFT (steps) | wall s |")
    print("|---|---|---|---|---|")
    for name, r in (("continuous", cont), ("fixed", fixed)):
        print(f"| {name} | {r['tokens_per_s']:.1f} | {r['ttft_p50_steps']:.0f} | "
              f"{r['ttft_p99_steps']:.0f} | {r['wall_s']:.2f} |")
    print(f"tokens/s ratio (cont/fixed): {result['tokens_ratio']:.2f} (claim: > 1)")
    print(f"p99 TTFT ratio (cont/fixed): {result['ttft_p99_ratio']:.2f} (claim: < 1)")
    print(f"preemptions: {cont['preemptions']}")
    # the claims the trajectory gate pins: continuous wins both axes (quick
    # mode is a smoke run — two fixed batches are too few to claim a ratio)
    wins = result["tokens_ratio"] > 1.0 and result["ttft_p99_ratio"] < 1.0
    return 0 if (wins or args.quick) else 1


if __name__ == "__main__":
    raise SystemExit(main())
