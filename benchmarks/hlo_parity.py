"""Zero-overhead proof, stronger than wall-clock: the interface and the raw
``jax.lax`` substrate must lower to the SAME collective HLO (op kinds,
counts, payload bytes).  The paper could only measure runtimes; with XLA the
compiled artifact itself is observable, so 'zero-cost abstraction' becomes a
checkable compiler-level property.

    PYTHONPATH=src python -m benchmarks.hlo_parity
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
OUT = ROOT / "artifacts" / "bench"

CHILD = r"""
import json
import jax, jax.numpy as jnp
from repro import core as mpx
from repro.core.hloanalysis import analyze_hlo

comm = mpx.world()
N = comm.size()
name = comm.axis_names[0]
lax = jax.lax

def _perm():
    return [(i, (i + 1) % N) for i in range(N)]

PAIRS = {
    "allreduce":      (lambda x: lax.psum(x, name),            lambda x: comm.allreduce(x)),
    "allgather":      (lambda x: lax.all_gather(x, name),      lambda x: comm.allgather(x)),
    "reduce_scatter": (lambda x: lax.psum_scatter(x, name, tiled=True),
                       lambda x: comm.reduce_scatter(x)),
    "alltoall":       (lambda x: lax.all_to_all(x, name, 0, 0, tiled=True),
                       lambda x: comm.alltoall(x)),
    "sendrecv":       (lambda x: lax.ppermute(x, name, _perm()),
                       lambda x: comm.shift(x, offset=1)),
}

rows = []
for op, (raw, iface) in PAIRS.items():
    x = jax.ShapeDtypeStruct((8 * N, 64), jnp.float32)
    stats = {}
    for kind, fn in (("raw", raw), ("iface", iface)):
        c = jax.jit(comm.spmd(fn, jit=False)).lower(x).compile()
        a = analyze_hlo(c.as_text())
        stats[kind] = {
            "counts": dict(a.collectives.count),
            "operand_bytes": a.collectives.total_operand_bytes,
            "wire_bytes": a.collectives.total_wire_bytes,
        }
    rows.append({"op": op, **stats,
                 "identical": stats["raw"] == stats["iface"]})
print("RESULT " + json.dumps(rows))
"""


def main():
    env = {
        **os.environ,
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        "PYTHONPATH": str(ROOT / "src"),
    }
    proc = subprocess.run(
        [sys.executable, "-c", CHILD], capture_output=True, text=True, env=env,
        timeout=900, cwd=str(ROOT),
    )
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-3000:])
    rows = None
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT "):
            rows = json.loads(line[len("RESULT "):])
    assert rows is not None
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "hlo_parity.json").write_text(json.dumps(rows, indent=1))
    lines = ["| op | raw collectives | iface collectives | payload bytes equal | identical |",
             "|---|---|---|---|---|"]
    for r in rows:
        eq = r["raw"]["operand_bytes"] == r["iface"]["operand_bytes"]
        lines.append(
            f"| {r['op']} | {r['raw']['counts']} | {r['iface']['counts']} | {eq} | "
            f"{r['identical']} |"
        )
    table = "\n".join(lines)
    (OUT / "hlo_parity.md").write_text(table + "\n")
    print(table)
    n_ok = sum(1 for r in rows if r["identical"])
    print(f"{n_ok}/{len(rows)} ops lower to identical collective HLO")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
