"""Zero-overhead proof, stronger than wall-clock: the interface and the raw
``jax.lax`` substrate must lower to the SAME collective HLO (op kinds,
counts, payload bytes).  The paper could only measure runtimes; with XLA the
compiled artifact itself is observable, so 'zero-cost abstraction' becomes a
checkable compiler-level property.

Also proves the **persistent path's steady state is free**: for every op
with an ``MPI_*_init`` constructor, the AOT-compiled executable inside the
:class:`~repro.core.futures.PersistentRequest` must contain exactly the same
collective kinds/counts/bytes as the per-call path — persistence amortizes
setup without perturbing the program XLA runs.

    PYTHONPATH=src python -m benchmarks.hlo_parity
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
OUT = ROOT / "artifacts" / "bench"

CHILD = r"""
import json
import jax, jax.numpy as jnp
from repro import core as mpx
from repro.core.hloanalysis import analyze_hlo

comm = mpx.world()
N = comm.size()
name = comm.axis_names[0]
lax = jax.lax

def _perm():
    return [(i, (i + 1) % N) for i in range(N)]

PAIRS = {
    "allreduce":      (lambda x: lax.psum(x, name),            lambda x: comm.allreduce(x)),
    "allgather":      (lambda x: lax.all_gather(x, name),      lambda x: comm.allgather(x)),
    "reduce_scatter": (lambda x: lax.psum_scatter(x, name, tiled=True),
                       lambda x: comm.reduce_scatter(x)),
    "alltoall":       (lambda x: lax.all_to_all(x, name, 0, 0, tiled=True),
                       lambda x: comm.alltoall(x)),
    "sendrecv":       (lambda x: lax.ppermute(x, name, _perm()),
                       lambda x: comm.shift(x, offset=1)),
}

# ops that also have a persistent (MPI_*_init) constructor
PERSISTENT_OPS = {"allreduce", "allgather", "reduce_scatter", "alltoall"}

def _coll_stats(hlo_text):
    a = analyze_hlo(hlo_text)
    return {
        "counts": dict(a.collectives.count),
        "operand_bytes": a.collectives.total_operand_bytes,
        "wire_bytes": a.collectives.total_wire_bytes,
    }

rows = []
for op, (raw, iface) in PAIRS.items():
    x = jax.ShapeDtypeStruct((8 * N, 64), jnp.float32)
    stats = {}
    for kind, fn in (("raw", raw), ("iface", iface)):
        c = jax.jit(comm.spmd(fn, jit=False)).lower(x).compile()
        stats[kind] = _coll_stats(c.as_text())
    row = {"op": op, **stats, "identical": stats["raw"] == stats["iface"]}
    if op in PERSISTENT_OPS:
        # steady-state HLO of the persistent path: the executable MPI_Start
        # re-fires must equal the per-call path's
        req = getattr(comm, op + "_init")(x)
        stats["persistent"] = _coll_stats(req.as_text())
        row["persistent"] = stats["persistent"]
        row["persistent_identical"] = stats["persistent"] == stats["iface"]
    rows.append(row)
print("RESULT " + json.dumps(rows))
"""


def main():
    env = {
        **os.environ,
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        "PYTHONPATH": str(ROOT / "src"),
    }
    proc = subprocess.run(
        [sys.executable, "-c", CHILD], capture_output=True, text=True, env=env,
        timeout=900, cwd=str(ROOT),
    )
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-3000:])
    rows = None
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT "):
            rows = json.loads(line[len("RESULT "):])
    assert rows is not None
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "hlo_parity.json").write_text(json.dumps(rows, indent=1))
    lines = ["| op | raw collectives | iface collectives | payload bytes equal | "
             "identical | persistent identical |",
             "|---|---|---|---|---|---|"]
    for r in rows:
        eq = r["raw"]["operand_bytes"] == r["iface"]["operand_bytes"]
        pid = r.get("persistent_identical", "—")
        lines.append(
            f"| {r['op']} | {r['raw']['counts']} | {r['iface']['counts']} | {eq} | "
            f"{r['identical']} | {pid} |"
        )
    table = "\n".join(lines)
    (OUT / "hlo_parity.md").write_text(table + "\n")
    print(table)
    n_ok = sum(1 for r in rows if r["identical"])
    print(f"{n_ok}/{len(rows)} ops lower to identical collective HLO")
    p_rows = [r for r in rows if "persistent_identical" in r]
    p_ok = sum(1 for r in p_rows if r["persistent_identical"])
    print(f"{p_ok}/{len(p_rows)} persistent ops: steady-state HLO identical to per-call")
    return 0 if p_ok == len(p_rows) and n_ok == len(rows) else 1


if __name__ == "__main__":
    raise SystemExit(main())
