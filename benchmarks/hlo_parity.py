"""Zero-overhead proof, stronger than wall-clock: the interface and the raw
``jax.lax`` substrate must lower to the SAME collective HLO (op kinds,
counts, payload bytes).  The paper could only measure runtimes; with XLA the
compiled artifact itself is observable, so 'zero-cost abstraction' becomes a
checkable compiler-level property.

Also proves the **persistent path's steady state is free**: for every op
with an ``MPI_*_init`` constructor, the AOT-compiled executable inside the
:class:`~repro.core.futures.PersistentRequest` must contain exactly the same
collective kinds/counts/bytes as the per-call path — persistence amortizes
setup without perturbing the program XLA runs.

    PYTHONPATH=src python -m benchmarks.hlo_parity
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
OUT = ROOT / "artifacts" / "bench"

CHILD = r"""
import json
import jax, jax.numpy as jnp
from repro import core as mpx
from repro.analysis import hlo as hlo_passes

comm = mpx.world()
N = comm.size()
name = comm.axis_names[0]
lax = jax.lax

def _perm():
    return [(i, (i + 1) % N) for i in range(N)]

PAIRS = {
    "allreduce":      (lambda x: lax.psum(x, name),            lambda x: comm.allreduce(x)),
    "allgather":      (lambda x: lax.all_gather(x, name),      lambda x: comm.allgather(x)),
    "reduce_scatter": (lambda x: lax.psum_scatter(x, name, tiled=True),
                       lambda x: comm.reduce_scatter(x)),
    "alltoall":       (lambda x: lax.all_to_all(x, name, 0, 0, tiled=True),
                       lambda x: comm.alltoall(x)),
    "sendrecv":       (lambda x: lax.ppermute(x, name, _perm()),
                       lambda x: comm.shift(x, offset=1)),
}

# ops that also have a persistent (MPI_*_init) constructor
PERSISTENT_OPS = {"allreduce", "allgather", "reduce_scatter", "alltoall"}

rows = []
for op, (raw, iface) in PAIRS.items():
    x = jax.ShapeDtypeStruct((8 * N, 64), jnp.float32)
    compiled = {
        kind: jax.jit(comm.spmd(fn, jit=False)).lower(x).compile()
        for kind, fn in (("raw", raw), ("iface", iface))
    }
    stats = {k: hlo_passes.stats_dict(c) for k, c in compiled.items()}
    row = {
        "op": op, **stats,
        "identical": hlo_passes.identical_lowering(
            compiled["raw"], compiled["iface"]).ok,
    }
    if op in PERSISTENT_OPS:
        # steady-state HLO of the persistent path: the executable MPI_Start
        # re-fires must equal the per-call path's
        req = getattr(comm, op + "_init")(x)
        row["persistent"] = hlo_passes.stats_dict(req)
        row["persistent_identical"] = hlo_passes.identical_lowering(
            req, compiled["iface"]).ok
    rows.append(row)

# neighborhood collectives (MPI 4.0 ch. 8): the SPARSITY proof —
# repro.analysis.hlo.neighbor_sparsity: axis-local collective-permutes whose
# wire bytes scale with the DEGREE (2), never a dense world all-to-all
# scaling with N.  The compiled artifact is the evidence, same as the
# zero-overhead claim above.
from repro.core import topology

cart = topology.cart_create(comm, (N,), (True,))
BLK = 64


def _neigh_a2a(x):
    return cart.neighbor_alltoall(x).get()


def _neigh_a2av(x):
    blocks, _ = cart.neighbor_alltoallv(x, [BLK // 2, BLK // 2]).get()
    return blocks


for op, fn, shape, dense_shape in (
    ("neighbor_alltoall", _neigh_a2a, (2, BLK, 64), (N * BLK, 64)),
    ("neighbor_alltoallv", _neigh_a2av, (2, BLK // 2, 64), (N * (BLK // 2), 64)),
):
    c = jax.jit(cart.spmd(fn, jit=False)).lower(
        jax.ShapeDtypeStruct(shape, jnp.float32)).compile()
    dense = jax.jit(comm.spmd(
        lambda x: lax.all_to_all(x, name, 0, 0, tiled=True), jit=False)).lower(
        jax.ShapeDtypeStruct(dense_shape, jnp.float32)).compile()
    verdict = hlo_passes.neighbor_sparsity(c, dense)
    rows.append({
        "op": op,
        "neighbor": hlo_passes.stats_dict(c),
        "dense": hlo_passes.stats_dict(dense),
        "sparse": verdict.detail["sparse"],
        "wire_fraction": verdict.detail["fraction"],
    })
# ring attention (kernels/ring_attention): the SCHEDULE proof —
# repro.analysis.hlo.ring_schedule: N ring steps over the periodic cart
# compile to exactly N−1 collective-permutes of the stacked local KV shard —
# 1/N of the global KV on the wire per step — and ZERO all-gathers: the
# compiled artifact shows the global KV is never materialised on any device.
from jax.sharding import PartitionSpec as P
from repro.core import _compat
from repro.kernels.ring_attention import ops as ring_ops

rc = topology.cart_create(comm, (N,), (True,), tag="repro://cart/ring-hlo")
rname = rc.axis_names[0]
B, S, H, Hk, D = 1, 64 * N, 4, 2, 32
rspec = P(None, rname, None, None)


def _ring_fn(q, k, v):
    return ring_ops.ring_attention(rc, q, k, v, causal=True, impl="ref")


qs = jax.ShapeDtypeStruct((B, S, H, D), jnp.float32)
kvs = jax.ShapeDtypeStruct((B, S, Hk, D), jnp.float32)
with rc.mesh:
    c = jax.jit(_compat.shard_map(
        _ring_fn, mesh=rc.mesh, in_specs=(rspec, rspec, rspec), out_specs=rspec
    )).lower(qs, kvs, kvs).compile()
kv_bytes = 2 * B * S * Hk * D * 4          # global K+V, fp32
verdict = hlo_passes.ring_schedule(c, N, shard_bytes=kv_bytes)
rows.append({
    "op": "ring_attention",
    "ring": hlo_passes.stats_dict(c),
    "permutes": verdict.detail["permutes"],
    "expected_permutes": verdict.detail["expected_permutes"],
    "kv_allgathers": verdict.detail["kv_allgathers"],
    "per_step_wire_fraction": verdict.detail["per_step_wire_fraction"],
    "schedule_ok": verdict.ok,
})
print("RESULT " + json.dumps(rows))
"""


def main():
    env = {
        **os.environ,
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        "PYTHONPATH": str(ROOT / "src"),
    }
    proc = subprocess.run(
        [sys.executable, "-c", CHILD], capture_output=True, text=True, env=env,
        timeout=900, cwd=str(ROOT),
    )
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-3000:])
    rows = None
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT "):
            rows = json.loads(line[len("RESULT "):])
    assert rows is not None
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "hlo_parity.json").write_text(json.dumps(rows, indent=1))
    parity_rows = [r for r in rows if "identical" in r]
    neighbor_rows = [r for r in rows if "sparse" in r]
    ring_rows = [r for r in rows if "schedule_ok" in r]
    lines = ["| op | raw collectives | iface collectives | payload bytes equal | "
             "identical | persistent identical |",
             "|---|---|---|---|---|---|"]
    for r in parity_rows:
        eq = r["raw"]["operand_bytes"] == r["iface"]["operand_bytes"]
        pid = r.get("persistent_identical", "—")
        lines.append(
            f"| {r['op']} | {r['raw']['counts']} | {r['iface']['counts']} | {eq} | "
            f"{r['identical']} | {pid} |"
        )
    lines += ["", "| neighborhood op | neighbor collectives | dense collectives | "
              "sparse (no all-to-all) | wire fraction |",
              "|---|---|---|---|---|"]
    for r in neighbor_rows:
        wf = r["wire_fraction"]
        lines.append(
            f"| {r['op']} | {r['neighbor']['counts']} | {r['dense']['counts']} | "
            f"{r['sparse']} | {wf:.3f} |"
        )
    lines += ["", "| ring schedule | permutes (want N−1) | KV all-gathers (want 0) | "
              "per-step wire fraction (want 1/N) | ok |",
              "|---|---|---|---|---|"]
    for r in ring_rows:
        lines.append(
            f"| {r['op']} | {r['permutes']} (={r['expected_permutes']}) | "
            f"{r['kv_allgathers']} | {r['per_step_wire_fraction']:.4f} | "
            f"{r['schedule_ok']} |"
        )
    table = "\n".join(lines)
    (OUT / "hlo_parity.md").write_text(table + "\n")
    print(table)
    n_ok = sum(1 for r in parity_rows if r["identical"])
    print(f"{n_ok}/{len(parity_rows)} ops lower to identical collective HLO")
    p_rows = [r for r in parity_rows if "persistent_identical" in r]
    p_ok = sum(1 for r in p_rows if r["persistent_identical"])
    print(f"{p_ok}/{len(p_rows)} persistent ops: steady-state HLO identical to per-call")
    s_ok = sum(1 for r in neighbor_rows if r["sparse"])
    worst_wf = max((r["wire_fraction"] or 0.0) for r in neighbor_rows) if neighbor_rows else 0.0
    print(f"{s_ok}/{len(neighbor_rows)} neighborhood ops lower sparse "
          f"(subgroup permutes, no dense world collective); worst wire "
          f"fraction vs dense alltoall: {worst_wf:.3f}")
    r_ok = sum(1 for r in ring_rows if r["schedule_ok"])
    print(f"{r_ok}/{len(ring_rows)} ring-attention schedules compile to "
          f"exactly N-1 collective-permutes, zero KV all-gathers, 1/N wire "
          f"per step")
    ok = (p_ok == len(p_rows) and n_ok == len(parity_rows)
          and s_ok == len(neighbor_rows) and r_ok == len(ring_rows))
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
