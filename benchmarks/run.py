"""Benchmark orchestrator — one benchmark per paper table/figure plus the
roofline deliverables:

* ``interface_overhead`` — the paper's Fig. 1 (mpiBench op set, raw vs
  interface, message lengths × device counts);
* ``hlo_parity``        — compiler-level zero-overhead proof (beyond-paper);
* ``roofline``          — §Roofline tables from the dry-run artifacts;
* ``train_throughput``  — end-to-end smoke-scale steps/s.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip", nargs="*", default=[])
    args = ap.parse_args(argv)

    from benchmarks import hlo_parity, interface_overhead, roofline, train_throughput

    rc = 0
    jobs = [
        ("interface_overhead", lambda: interface_overhead.main(
            ["--quick"] if args.quick else [])),
        ("hlo_parity", lambda: hlo_parity.main()),
        ("roofline(single-pod)", lambda: roofline.main(["--mesh", "pod_16x16"])),
        ("roofline(multi-pod)", lambda: roofline.main(["--mesh", "multipod_2x16x16"])),
        ("train_throughput", lambda: train_throughput.main(
            ["--steps", "5"] if args.quick else [])),
    ]
    for name, fn in jobs:
        if any(s in name for s in args.skip):
            print(f"=== {name}: skipped")
            continue
        print(f"\n=== {name} ===")
        t0 = time.time()
        try:
            r = fn()
            rc = rc or (r or 0)
        except Exception as e:  # pragma: no cover
            print(f"{name} FAILED: {e}")
            rc = 1
        print(f"=== {name} done in {time.time()-t0:.0f}s")
    return rc


if __name__ == "__main__":
    sys.exit(main())
