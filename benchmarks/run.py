"""Benchmark orchestrator — one benchmark per paper table/figure plus the
roofline deliverables:

* ``interface_overhead`` — the paper's Fig. 1 (mpiBench op set, raw vs
  interface, message lengths × device counts), plus the persistent, RMA,
  neighborhood and I/O series;
* ``hlo_parity``        — compiler-level zero-overhead + neighbor-sparsity
  proof (beyond-paper);
* ``roofline``          — §Roofline tables from the dry-run artifacts;
* ``train_throughput``  — end-to-end smoke-scale steps/s.

    PYTHONPATH=src python -m benchmarks.run [--quick]

**Bench trajectory**: after any run (or standalone with ``--summary``), the
tracked series are condensed from ``artifacts/bench/*.json`` into a
per-commit ``artifacts/bench/BENCH_summary.json``; ``--gate
benchmarks/baseline.json`` compares it against the committed baseline and
fails on a >25% regression of any tracked series — the CI step that keeps
the perf trajectory honest.  Regenerate the baseline by copying a fresh
summary over ``benchmarks/baseline.json`` when a change legitimately moves
a series.
"""

from __future__ import annotations

import argparse
import json
import math
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
OUT = ROOT / "artifacts" / "bench"

#: Tracked trajectory series → direction ("lower"/"higher" = which way is
#: better).  Ratio-type series (geomeans of iface/raw) are preferred over
#: absolute wall-clock numbers: they stay comparable across CI machines.
TRACKED = {
    "overhead_geomean_ratio": "lower",      # interface/raw, mpiBench set
    "persistent_geomean_ratio": "lower",    # persistent steady / per-call
    "rma_geomean_ratio": "lower",           # window ops / raw lowering
    "neighbor_allgather_ratio": "lower",    # ch. 8 exchange / raw halo permutes
    "neighbor_wire_fraction": "lower",      # neighbor vs dense wire bytes (HLO)
    "neighbor_sparse": "higher",            # 1.0 = no dense world collective
    "io_overlap_ratio": "lower",            # async/serial checkpoint wall-clock
    "io_commits_per_save": "lower",         # manifest sync points (claim: 1)
    "hlo_identical_frac": "higher",         # zero-overhead proof coverage
    "serving_overhead_ratio": "lower",      # engine.step / raw decode loop body
    "serving_tokens_ratio": "higher",       # continuous / fixed tokens-per-s
    "serving_ttft_p99_ratio": "lower",      # continuous / fixed p99 TTFT
    "ring_attention_tax": "lower",          # fused ring / raw ppermute schedule
    "ring_steps_per_s": "higher",           # long-context ring train steps/s
    "elastic_recovery_steps": "lower",      # steps replayed per evicted rank
    "elastic_rebuild_ratio": "lower",       # shrink-rebuild-restore / clean step
    "autotuner_regret": "lower",            # greedy plan score / brute-force best
}


def _geomean(xs):
    xs = [max(float(x), 1e-9) for x in xs]
    return math.exp(sum(math.log(x) for x in xs) / len(xs)) if xs else None


def summarize(out_dir: Path = OUT) -> dict:
    """Condense the benchmark artifacts into the tracked series.  Series
    whose source artifact is missing are omitted (the gate treats a
    baseline series missing from the summary as a failure, so a partial CI
    run cannot silently pass)."""

    summary: dict[str, float] = {}

    iface = out_dir / "interface_overhead.json"
    if iface.exists():
        rows = json.loads(iface.read_text())
        plain = [r for r in rows if "series" not in r]
        if plain:
            summary["overhead_geomean_ratio"] = _geomean(
                [r["iface_us"] / max(r["raw_us"], 1e-9) for r in plain]
            )
        pers = [r for r in rows if "persist_us" in r]
        if pers:
            summary["persistent_geomean_ratio"] = _geomean(
                [r["persist_us"] / max(r["percall_us"], 1e-9) for r in pers]
            )
        rma = [r for r in rows if r.get("series") == "rma"]
        if rma:
            summary["rma_geomean_ratio"] = _geomean(
                [r["iface_us"] / max(r["raw_us"], 1e-9) for r in rma]
            )
        neigh = [
            r for r in rows
            if r.get("series") == "neighbor" and r["op"] == "neighbor_allgather"
        ]
        if neigh:
            summary["neighbor_allgather_ratio"] = _geomean(
                [r["iface_us"] / max(r["raw_us"], 1e-9) for r in neigh]
            )
        serving = [r for r in rows if r.get("series") == "serving"]
        if serving:
            summary["serving_overhead_ratio"] = _geomean(
                [r["iface_us"] / max(r["raw_us"], 1e-9) for r in serving]
            )
        ring = [r for r in rows if r.get("series") == "ring"]
        if ring:
            summary["ring_attention_tax"] = _geomean(
                [r["iface_us"] / max(r["raw_us"], 1e-9) for r in ring]
            )

    sb = out_dir / "serving_bench.json"
    if sb.exists():
        r = json.loads(sb.read_text())
        summary["serving_tokens_ratio"] = float(r["tokens_ratio"])
        summary["serving_ttft_p99_ratio"] = float(r["ttft_p99_ratio"])

    io = out_dir / "io_overhead.json"
    if io.exists():
        rows = json.loads(io.read_text())
        if rows:
            summary["io_overlap_ratio"] = max(r["overlap_ratio"] for r in rows)
            summary["io_commits_per_save"] = max(
                r["manifest_commits_per_save"] for r in rows
            )

    ring_tp = out_dir / "train_throughput_ring.json"
    if ring_tp.exists():
        rows = [r for r in json.loads(ring_tp.read_text()) if r.get("ring", 0) > 1]
        if rows:
            summary["ring_steps_per_s"] = max(r["steps_per_s"] for r in rows)

    el = out_dir / "elastic_bench.json"
    if el.exists():
        r = json.loads(el.read_text())
        summary["elastic_recovery_steps"] = float(r["recovery_steps"])
        summary["elastic_rebuild_ratio"] = float(r["rebuild_ratio"])

    regret = out_dir / "autotuner_regret.json"
    if regret.exists():
        r = json.loads(regret.read_text())
        summary["autotuner_regret"] = float(r["autotuner_regret"])

    parity = out_dir / "hlo_parity.json"
    if parity.exists():
        rows = json.loads(parity.read_text())
        ident = [r for r in rows if "identical" in r]
        if ident:
            summary["hlo_identical_frac"] = sum(
                1 for r in ident if r["identical"]
            ) / len(ident)
        neigh = [r for r in rows if "sparse" in r]
        if neigh:
            summary["neighbor_sparse"] = (
                1.0 if all(r["sparse"] for r in neigh) else 0.0
            )
            fracs = [r["wire_fraction"] for r in neigh if r["wire_fraction"]]
            if fracs:
                summary["neighbor_wire_fraction"] = max(fracs)

    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / "BENCH_summary.json").write_text(json.dumps(summary, indent=1))
    return summary


def gate(summary: dict, baseline_path: Path, tolerance: float = 0.25) -> int:
    """Fail (rc 1) on a regression past tolerance of any baseline series.

    Baseline entries are either a bare number (default 25% tolerance) or
    ``{"value": v, "tolerance": t}`` for series with a different noise
    floor — the compile-time-dominated persistent ratio gets a wide band
    (any meaningful regression is orders of magnitude), the deterministic
    HLO proof fractions an exact one.  "Regression" is direction-aware: for
    a lower-is-better series the gate trips when ``value > baseline *
    (1 + tolerance)``; for higher-is-better when ``value < baseline /
    (1 + tolerance)``.  A tracked series present in the baseline but absent
    from the summary also fails — a partial bench run must not read green.
    """

    baseline = json.loads(Path(baseline_path).read_text())
    rc = 0
    # a tracked series present in the summary but absent from the baseline
    # would never gate at all (the loop below iterates the baseline) — warn
    # loudly instead of staying silently unguarded
    unguarded = sorted(
        name for name in summary if name in TRACKED and name not in baseline
    )
    for name in unguarded:
        print(
            f"WARNING: tracked series {name!r} has no entry in "
            f"{baseline_path} and is NOT gated — reseed the baseline "
            f"(python -m benchmarks.run --summary --reseed) to guard it."
        )
    print(f"\nbench gate vs {baseline_path} (default tolerance {tolerance:.0%}):")
    print("| series | baseline | current | direction | tolerance | verdict |")
    print("|---|---|---|---|---|---|")
    for name, entry in baseline.items():
        if isinstance(entry, dict):
            base, tol = float(entry["value"]), float(entry.get("tolerance", tolerance))
        else:
            base, tol = float(entry), tolerance
        direction = TRACKED.get(name, "lower")
        cur = summary.get(name)
        if cur is None:
            verdict = "FAIL (missing)"
            rc = 1
        elif direction == "lower":
            ok = cur <= base * (1 + tol)
            verdict = "ok" if ok else "FAIL"
            rc = rc if ok else 1
        else:
            ok = cur >= base / (1 + tol)
            verdict = "ok" if ok else "FAIL"
            rc = rc if ok else 1
        cur_s = "—" if cur is None else f"{cur:.4f}"
        print(f"| {name} | {base:.4f} | {cur_s} | {direction} | {tol:.0%} | {verdict} |")
    return rc


def reseed(summary: dict, baseline_path: Path) -> None:
    """Rewrite the committed baseline from the current summary: every
    tracked series present in the summary gets its measured value, keeping
    an existing ``{"value", "tolerance"}`` entry's tolerance (the per-series
    noise floor is curated, the value is measured).  Series in the baseline
    but missing from this summary are kept untouched — reseeding after a
    partial run must not drop guards."""

    path = Path(baseline_path)
    baseline = json.loads(path.read_text()) if path.exists() else {}
    for name, value in summary.items():
        if name not in TRACKED:
            continue
        old = baseline.get(name)
        if isinstance(old, dict):
            baseline[name] = {**old, "value": round(float(value), 4)}
        else:
            baseline[name] = round(float(value), 4)
    path.write_text(json.dumps(baseline, indent=1, sort_keys=True) + "\n")
    print(f"reseeded {path} from current summary ({len(summary)} series)")


def record(summary: dict, history_dir: Path | None = None) -> Path:
    """Append one dated summary row to the committed bench history
    (``benchmarks/history/history.jsonl``, one JSON object per line) — the
    trajectory of the tracked series across PRs, durable where
    ``artifacts/`` is not.  Rows carry the date and short commit so a plot
    over the file is a perf timeline of the repo."""

    import datetime
    import subprocess

    history_dir = history_dir or ROOT / "benchmarks" / "history"
    history_dir.mkdir(parents=True, exist_ok=True)
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, cwd=str(ROOT), timeout=30,
        ).stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        commit = None    # no git binary / not a checkout / timeout
    row = {
        "date": datetime.date.today().isoformat(),
        "commit": commit,
        "series": {
            k: round(float(v), 4) for k, v in sorted(summary.items())
            if k in TRACKED
        },
    }
    path = history_dir / "history.jsonl"
    with path.open("a") as f:
        f.write(json.dumps(row, sort_keys=True) + "\n")
    print(f"recorded bench summary row to {path} ({row['date']}, {commit})")
    return path


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip", nargs="*", default=[])
    ap.add_argument(
        "--summary",
        action="store_true",
        help="only condense existing artifacts into BENCH_summary.json "
        "(skip running the benchmarks)",
    )
    ap.add_argument(
        "--gate",
        default=None,
        metavar="BASELINE",
        help="compare the summary against a committed baseline JSON; "
        "exit 1 on >25%% regression of any tracked series",
    )
    ap.add_argument(
        "--record",
        action="store_true",
        help="append a dated row of the tracked series to "
        "benchmarks/history/history.jsonl (the committed perf trajectory)",
    )
    ap.add_argument(
        "--reseed",
        nargs="?",
        const=str(ROOT / "benchmarks" / "baseline.json"),
        default=None,
        metavar="BASELINE",
        help="rewrite the baseline's values from the current summary "
        "(tolerances of existing entries are kept); defaults to "
        "benchmarks/baseline.json",
    )
    args = ap.parse_args(argv)

    rc = 0
    if not args.summary:
        from benchmarks import (
            elastic_bench,
            hlo_parity,
            interface_overhead,
            roofline,
            serving_bench,
            train_throughput,
        )

        jobs = [
            ("interface_overhead", lambda: interface_overhead.main(
                ["--quick"] if args.quick else [])),
            ("serving_bench", lambda: serving_bench.main(
                ["--quick"] if args.quick else [])),
            ("hlo_parity", lambda: hlo_parity.main()),
            ("roofline(single-pod)", lambda: roofline.main(["--mesh", "pod_16x16"])),
            ("roofline(multi-pod)", lambda: roofline.main(["--mesh", "multipod_2x16x16"])),
            ("train_throughput", lambda: train_throughput.main(
                ["--steps", "5"] if args.quick else [])),
            # long-context ring mode: sequence sharded over a (2, 4) cart
            # ring — a global length one device's dense path would not train
            ("train_throughput(ring)", lambda: train_throughput.main(
                ["--ring", "4", "--steps", "2", "--seq", "512"] if args.quick
                else ["--ring", "4", "--steps", "3", "--seq", "1024"])),
            # injected rank eviction: steps replayed + shrink-rebuild cost
            ("elastic_bench", lambda: elastic_bench.main()),
            # autotuner: greedy coordinate-descent vs the brute-force
            # roofline minimum over the fixed regret matrix (deterministic)
            ("roofline(regret)", lambda: roofline.main(["--regret"])),
        ]
        for name, fn in jobs:
            if any(s in name for s in args.skip):
                print(f"=== {name}: skipped")
                continue
            print(f"\n=== {name} ===")
            t0 = time.time()
            try:
                r = fn()
                rc = rc or (r or 0)
            except Exception as e:  # pragma: no cover  # lint: allow-broad-except — reported, fails the run
                print(f"{name} FAILED: {e}")
                rc = 1
            print(f"=== {name} done in {time.time()-t0:.0f}s")

    summary = summarize()
    print("\nBENCH_summary.json:")
    for k, v in summary.items():
        print(f"  {k}: {v:.4f}")
    if args.record:
        record(summary)
    if args.reseed:
        reseed(summary, Path(args.reseed))
    if args.gate:
        rc = gate(summary, Path(args.gate)) or rc
    return rc


if __name__ == "__main__":
    sys.exit(main())
