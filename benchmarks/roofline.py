"""§Roofline: assemble the per-(arch × shape × mesh) roofline table from the
dry-run artifacts (artifacts/dryrun/*.json).

    compute_s    = HLO_FLOPs / peak_FLOPs          (per chip, trip-corrected)
    memory_s     = HLO_bytes / HBM_bw
    collective_s = collective operand bytes / ICI link bw

plus MODEL_FLOPS = 6·N(_active)·D (train) or 2·N·D (serve), the useful-flop
ratio, peak memory per device, and the dominant term with a one-line lever.

    PYTHONPATH=src python -m benchmarks.roofline [--mesh pod_16x16] [--tag X]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
ART = ROOT / "artifacts" / "dryrun"
OUT = ROOT / "artifacts" / "bench"

LEVERS = {
    "compute_s": "raise arithmetic efficiency: fewer recompute passes (remat policy), "
                 "fused kernels, larger per-chip tile",
    "memory_s": "cut HBM traffic: microbatching, bf16/int8 intermediates, "
                "fused attention (no S² materialisation), int8 KV cache",
    "collective_s": "cut wire bytes: shard instead of replicate the hot tensor, "
                    "overlap (all_gather_matmul), int8 gradient compression, "
                    "hierarchical cross-pod reduce",
}


def load(mesh: str, tag: str) -> list[dict]:
    rows = []
    suffix = f"__{mesh}" + (f"__{tag}" if tag else "")
    for p in sorted(ART.glob(f"*{suffix}.json")):
        r = json.loads(p.read_text())
        if (r.get("tag") or "") != tag:
            continue
        rows.append(r)
    return rows


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}µs"


def table(rows: list[dict]) -> str:
    head = ("| arch | shape | kind | compute | memory | collective | dominant | "
            "peak GiB/dev | useful-flop ratio |")
    lines = [head, "|" + "---|" * 9]
    for r in rows:
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | skipped: "
                f"{r['reason'][:40]}… | — | — |"
            )
            continue
        t = r["roofline"]
        peak = r["memory"].get("peak_bytes_per_device", 0) / 2**30
        ratio = r.get("useful_flop_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | {fmt_s(t['compute_s'])} | "
            f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
            f"**{t['dominant'].replace('_s','')}** | {peak:.1f} | "
            f"{ratio:.2f} |" if ratio is not None else "| ? |"
        )
    return "\n".join(lines)


#: the regret matrix: architectures with genuinely different legal spaces
#: (dense GQA, wide dense, fine-grained MoE, coarse MoE), all on the train
#: cell at pod scale — enough devices that the axes actually compete.
REGRET_CELLS = (
    ("gemma2_9b", "train_4k", 256),
    ("qwen1_5_32b", "train_4k", 256),
    ("deepseek_v2_236b", "train_4k", 256),
    ("grok_1_314b", "train_4k", 256),
)


def regret(argv_cells=REGRET_CELLS) -> dict:
    """``autotuner_regret``: coordinate-descent score ÷ exhaustive minimum
    per cell; the tracked series is the worst (max) ratio.  Deterministic —
    both searches are pure arithmetic over the same candidate set — so the
    gate can hold a tight tolerance: 1.0 means greedy found the optimum
    everywhere, and the brute-force denominator IS the enumerated minimum
    (the tuner's acceptance criterion, checked on every CI run)."""

    from repro import tune

    cells = []
    worst = 1.0
    for arch, shape, devices in argv_cells:
        best = tune.tune(arch, shape, devices, mode="exhaustive",
                         register=False, calibrate=False, slices=1)
        greedy = tune.tune(arch, shape, devices, mode="coordinate",
                           register=False, calibrate=False, slices=1)
        ratio = greedy.score.step_s / best.score.step_s
        worst = max(worst, ratio)
        cells.append({
            "arch": arch, "shape": shape, "devices": devices,
            "best": best.plan.slug(), "best_step_s": best.score.step_s,
            "greedy": greedy.plan.slug(), "greedy_step_s": greedy.score.step_s,
            "regret": ratio,
            "n_candidates": best.n_candidates,
            "greedy_scored": greedy.n_scored,
        })
        print(f"regret {arch} x {shape} @{devices}: {ratio:.4f} "
              f"(greedy {greedy.plan.slug()} vs best {best.plan.slug()}, "
              f"{greedy.n_scored}/{best.n_candidates} scored)")
    out = {"autotuner_regret": worst, "cells": cells}
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "autotuner_regret.json").write_text(json.dumps(out, indent=1))
    print(f"autotuner_regret (worst cell): {worst:.4f}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod_16x16")
    ap.add_argument("--tag", default="")
    ap.add_argument(
        "--regret",
        action="store_true",
        help="score the autotuner: coordinate-descent vs brute-force minimum "
        "over the fixed regret matrix; writes autotuner_regret.json",
    )
    args = ap.parse_args(argv)

    if args.regret:
        regret()
        return 0

    rows = load(args.mesh, args.tag)
    if not rows:
        print(f"no artifacts for mesh={args.mesh} tag={args.tag!r}; run repro.launch.dryrun")
        return 1
    t = table(rows)
    OUT.mkdir(parents=True, exist_ok=True)
    name = f"roofline_{args.mesh}" + (f"_{args.tag}" if args.tag else "")
    (OUT / f"{name}.md").write_text(t + "\n")
    print(t)

    # per-dominant-term lever notes
    doms = {}
    for r in rows:
        if r["status"] == "ok":
            doms.setdefault(r["roofline"]["dominant"], []).append(
                f"{r['arch']}×{r['shape']}"
            )
    print()
    for dom, cells in sorted(doms.items()):
        print(f"{dom}-bound ({len(cells)} cells): {LEVERS[dom]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
