"""End-to-end train/serve throughput on the host devices (smoke-scale
models; the production numbers are the §Roofline projections).

    PYTHONPATH=src python -m benchmarks.train_throughput [--arch gemma2_9b]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
OUT = ROOT / "artifacts" / "bench"

CHILD = r"""
import json, sys, time
import jax
from repro.configs import base
from repro.launch.mesh import make_host_mesh
from repro.runtime.trainer import Trainer, TrainerConfig

arch, steps, batch, seq = sys.argv[1], int(sys.argv[2]), int(sys.argv[3]), int(sys.argv[4])
persistent = sys.argv[5] == "persistent"
ring = int(sys.argv[6]) if len(sys.argv) > 6 else 0
cfg = base.get_smoke_config(arch)
pcfg = base.get_parallel(arch)
mesh = make_host_mesh()
t = Trainer(cfg, pcfg,
            TrainerConfig(steps=steps, log_every=steps, persistent=persistent,
                          ring_attention=ring),
            mesh, seq_len=seq, global_batch=batch)
mesh = t.mesh    # ring/pipeline modes re-form the communicator (and mesh)
pcfg = t.pcfg
params, opt_state = t.init_state()
step_fn = t.compile(params, opt_state)
b = t.pipeline.device_batch(0, mesh, pcfg)
params, opt_state, m = step_fn(params, opt_state, b)   # warm
jax.block_until_ready(m["loss"])
t0 = time.perf_counter()
for i in range(steps):
    b = t.pipeline.device_batch(i, mesh, pcfg)
    params, opt_state, m = step_fn(params, opt_state, b)
jax.block_until_ready(m["loss"])
dt = time.perf_counter() - t0
print("RESULT " + json.dumps({
    "arch": arch, "steps": steps, "s_per_step": dt / steps,
    "tokens_per_s": batch * seq * steps / dt,
    "steps_per_s": steps / dt,
    "final_loss": float(m["loss"]),
    "seq": seq, "ring": ring,
    "mode": "ring" if ring > 1 else ("persistent" if persistent else "per-call"),
}))
"""


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", nargs="*", default=["gemma2_9b", "mamba2_2_7b", "grok_1_314b"])
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--per-call", dest="per_call", action="store_true",
                    help="plain-jit step instead of the persistent engine")
    ap.add_argument("--ring", type=int, default=0,
                    help="ring-attention mode: fold the devices onto a "
                    "(data, ring) cart of this ring size and shard the "
                    "sequence — run at --seq lengths one device's KV budget "
                    "cannot hold (reports ring_steps_per_s)")
    args = ap.parse_args(argv)
    if args.ring > 1:
        # the long-context configuration: sequence sharded over the ring,
        # dense family only (the ring path lives in the attention layers)
        args.archs = [a for a in args.archs if a == "gemma2_9b"] or ["gemma2_9b"]

    env = {
        **os.environ,
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        "PYTHONPATH": str(ROOT / "src"),
    }
    rows = []
    for arch in args.archs:
        proc = subprocess.run(
            [sys.executable, "-c", CHILD, arch, str(args.steps), str(args.batch),
             str(args.seq), "per-call" if args.per_call else "persistent",
             str(args.ring)],
            capture_output=True, text=True, env=env, timeout=1800, cwd=str(ROOT),
        )
        if proc.returncode != 0:
            print(f"{arch}: FAILED\n{proc.stderr[-1500:]}")
            continue
        for line in proc.stdout.splitlines():
            if line.startswith("RESULT "):
                r = json.loads(line[len("RESULT "):])
                rows.append(r)
                print(f"{arch}: {r['s_per_step']*1e3:.1f} ms/step, "
                      f"{r['tokens_per_s']:.0f} tok/s (smoke scale, 8 virtual devs)")
    OUT.mkdir(parents=True, exist_ok=True)
    name = "train_throughput_ring.json" if args.ring > 1 else "train_throughput.json"
    (OUT / name).write_text(json.dumps(rows, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
