"""The paper's experiment (Fig. 1), adapted: runtime of the mpiBench
operation set through (a) the raw substrate — bare ``jax.lax`` collectives
inside ``shard_map`` — and (b) this library's modern interface, for varying
message lengths and device counts.  The paper's claim to reproduce: *no
recognizable disparity* between the two.

Extends the figure with the **persistent-vs-per-call** series (MPI 4.0
persistent collectives): for each ``<op>_init``-capable operation it also
measures (c) the per-call path paying full setup — trace + lower + compile —
every call, (d) the one-time ``<op>_init`` setup cost, and (e) the
persistent steady state (``MPI_Start`` re-fires of the compiled executable).
The claim: setup is amortized — persistent steady state ≤ the per-call path.

And with the **RMA series** (MPI 4.0 chapter 12, one-sided): window
``put``/``get``/``accumulate`` against the raw collective each lowers to
(``collective-permute`` / masked ``psum``), plus the window-epoch
(``fence``/``fence``) cost against a bare ``optimization_barrier`` — the
interface tax of the epoch machinery, masking and datatype plumbing.

And with the **neighborhood series** (MPI 4.0 chapter 8, virtual
topologies): the cart ``neighbor_allgather`` against the two hand-written
halo permutes it lowers to (interface tax ≈ 1), and the sparse
``neighbor_alltoall`` against the dense world ``all_to_all`` one would use
without topologies, at equal per-neighbor payload.

And with the **I/O series** (MPI 4.0 chapter 14, nonblocking collective
file I/O): checkpoint write bandwidth, the issue latency of a request-based
async save (the synchronous part is only the device→host gather), and the
**overlap** claim — an async save plus a compute span costs ~max(I/O,
compute) wall-clock where the synchronous form costs the sum — with the
manifest-commit count per save (exactly one: the single sync point).

Run directly (spawns subprocesses with N virtual devices):

    PYTHONPATH=src python -m benchmarks.interface_overhead [--quick]

Writes artifacts/bench/interface_overhead.json + a markdown table.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
OUT = ROOT / "artifacts" / "bench"

# the measurement body executed in a subprocess with N virtual devices
CHILD = r"""
import json, sys, time
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import core as mpx

msg_lens = json.loads(sys.argv[1])   # element counts (f32)
reps = int(sys.argv[2])

comm = mpx.world()
N = comm.size()
name = comm.axis_names[0]
lax = jax.lax

def _perm():
    return [(i, (i + 1) % N) for i in range(N)]

# (op, raw-lax implementation, interface implementation) — the mpiBench set
OPS = {
    "barrier":        (lambda x: lax.psum(jnp.zeros((), x.dtype), name),
                       lambda x: (comm.barrier(), x)[1] * 0.0),
    "broadcast":      (lambda x: lax.all_gather(x[None] * 0, name)[0] + x,
                       lambda x: comm.broadcast(x, root=0)),
    "allreduce":      (lambda x: lax.psum(x, name),
                       lambda x: comm.allreduce(x)),
    "reduce":         (lambda x: lax.psum(x, name),
                       lambda x: comm.reduce(x, root=0)),
    "allgather":      (lambda x: lax.all_gather(x, name),
                       lambda x: comm.allgather(x)),
    "gather":         (lambda x: lax.all_gather(x, name),
                       lambda x: comm.gather(x, root=0)),
    "scatter":        (lambda x: lax.dynamic_slice_in_dim(
                           lax.all_to_all(x, name, 0, 0, tiled=True),
                           0, x.shape[0] // N, axis=0),
                       lambda x: comm.scatter(x, root=0)),
    "alltoall":       (lambda x: lax.all_to_all(x, name, 0, 0, tiled=True),
                       lambda x: comm.alltoall(x)),
    "reduce_scatter": (lambda x: lax.psum_scatter(x, name, tiled=True),
                       lambda x: comm.reduce_scatter(x)),
    "sendrecv":       (lambda x: lax.ppermute(x, name, _perm()),
                       lambda x: comm.shift(x, offset=1)),
    "scan":           (lambda x: jax.lax.associative_scan(
                           jnp.add, lax.all_gather(x, name), axis=0)[
                           lax.axis_index(name)],
                       lambda x: comm.scan(x)),
}

def bench(fn, n_elems):
    x = jnp.ones((max(N, n_elems // N * N),), jnp.float32)  # divisible shape
    jitted = comm.spmd(fn)
    out = jitted(x); jax.block_until_ready(out)              # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = jitted(x)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6           # us/call

# ops with persistent (MPI_*_init) constructors: persistent-vs-per-call series
PERSISTENT_OPS = ("allreduce", "allgather", "reduce_scatter", "alltoall")

def bench_persistent(op, n_elems):
    x = jnp.ones((max(N, n_elems // N * N),), jnp.float32)
    iface = OPS[op][1]
    # (d) one-time setup: trace + lower + AOT compile + first fire
    t0 = time.perf_counter()
    req = getattr(comm, op + "_init")(x)
    call = req.requests[0]                                   # the MPI_Start path
    out = call(x); jax.block_until_ready(out)
    init_us = (time.perf_counter() - t0) * 1e6
    # (e) persistent steady state: re-fire the compiled executable
    t0 = time.perf_counter()
    for _ in range(reps):
        out = call(x)
    jax.block_until_ready(out)
    persist_us = (time.perf_counter() - t0) / reps * 1e6
    # (c) per-call path: pay full setup every call (a fresh function object
    # defeats the jit cache, exactly what a non-persistent MPI op does to
    # its argument-list setup)
    pc_reps = min(reps, 3)
    t0 = time.perf_counter()
    for _ in range(pc_reps):
        fresh = comm.spmd((lambda f: lambda xx: f(xx))(iface))
        out = fresh(x)
    jax.block_until_ready(out)
    percall_us = (time.perf_counter() - t0) / pc_reps * 1e6
    return init_us, persist_us, percall_us

# RMA series: window operations vs the raw collective each lowers to, and
# the window-epoch cost vs a bare optimization barrier
from repro.core import onesided
from repro.core.descriptors import ReduceOp

RING = _perm()

def _win(x):
    w = onesided.Window(comm, x)
    w.fence()
    return w

RMA_OPS = {
    "win_put":        (lambda x: lax.ppermute(x, name, RING),
                       lambda x: _win(x).put(x, RING).fence().buffer),
    # get(RING) lowers to the same s->d permute as put (origin d reads s)
    "win_get":        (lambda x: lax.ppermute(x, name, RING),
                       lambda x: _win(x).get(RING)),
    "win_accumulate": (lambda x: jnp.where(lax.axis_index(name) == 0,
                                           x + lax.psum(x, name), x),
                       lambda x: _win(x).accumulate(x, target=0).fence().buffer),
    "win_fence":      (lambda x: lax.optimization_barrier(x),
                       lambda x: _win(x).fence().buffer),
}

# neighborhood series (MPI 4.0 ch. 8): (a) interface tax of the cart
# neighbor_allgather vs the two hand-written halo permutes it lowers to
# (claim: ~1.0), and (b) the sparse neighbor_alltoall vs the dense world
# all_to_all you would use without topologies, at equal per-neighbor
# payload (claim: < 1 once N outgrows the degree)
from repro.core import topology

cart = topology.cart_create(comm, (N,), (True,))
PLUS = [(i, (i + 1) % N) for i in range(N)]
MINUS = [(i, (i - 1) % N) for i in range(N)]

def bench_on(spmd, fn, x):
    jitted = spmd(fn)
    out = jitted(x); jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = jitted(x)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6

def raw_halo(x):
    return jnp.stack([lax.ppermute(x, name, PLUS), lax.ppermute(x, name, MINUS)])

def bench_neighbor(n_elems):
    blk = max(1, n_elems // N)
    x_blk = jnp.ones((blk,), jnp.float32)
    x_nb = jnp.ones((2, blk), jnp.float32)
    x_dense = jnp.ones((N * blk,), jnp.float32)
    return [
        {"op": "neighbor_allgather", "series": "neighbor",
         "raw_us": bench_on(comm.spmd, raw_halo, x_blk),
         "iface_us": bench_on(cart.spmd,
                              lambda x: cart.neighbor_allgather(x).get(), x_blk)},
        {"op": "neighbor_alltoall", "series": "neighbor",
         "raw_us": bench_on(comm.spmd,
                            lambda x: lax.all_to_all(x, name, 0, 0, tiled=True),
                            x_dense),
         "iface_us": bench_on(cart.spmd,
                              lambda x: cart.neighbor_alltoall(x).get(), x_nb)},
    ]

rows = []
for n in msg_lens:
    for op, (raw, iface) in OPS.items():
        row = {
            "devices": N, "msg_elems": n, "op": op,
            "raw_us": bench(raw, n), "iface_us": bench(iface, n),
        }
        if op in PERSISTENT_OPS:
            row["init_us"], row["persist_us"], row["percall_us"] = bench_persistent(op, n)
        rows.append(row)
    for op, (raw, iface) in RMA_OPS.items():
        rows.append({
            "devices": N, "msg_elems": n, "op": op, "series": "rma",
            "raw_us": bench(raw, n), "iface_us": bench(iface, n),
        })
    for row in bench_neighbor(n):
        rows.append({"devices": N, "msg_elems": n, **row})
print("RESULT " + json.dumps(rows))
"""


# ring-attention series: the fused path (cart ring + TraceFuture/when_all
# rotate-while-compute + custom_vjp, kernels/ring_attention/ops.py) against
# the raw hand-written schedule — bare lax.ppermute and the same online-block
# update, no futures, no cart, no VJP boundary.  Both are trace-time
# abstractions over the same dataflow, so the claim is the zero-overhead one:
# tax ~ 1.0 (gated at <= 1.05 in baseline.json).
RING_CHILD = r"""
import gc, json, sys, time
import jax, jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P
from repro import core as mpx
from repro.core import _compat, topology
from repro.kernels.ring_attention import kernel as rk
from repro.kernels.ring_attention import ops as ring_ops

reps = int(sys.argv[1])
comm = mpx.world()
N = comm.size()
cart = topology.cart_create(comm, (N,), (True,), tag="repro://cart/ring-bench")
name = cart.axis_names[0]
mesh = cart.mesh
B, S, H, D = 1, 128 * N, 4, 64
shard = S // N
scale = D ** -0.5
spec = P(None, name, None, None)
perm = [(i, (i + 1) % N) for i in range(N)]

def fused(q, k, v):
    return ring_ops.ring_attention(cart, q, k, v, causal=True, impl="ref")

def raw(q, k, v):
    qt = q.transpose(0, 2, 1, 3)
    kv = jnp.stack([k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)])
    idx = lax.axis_index(name)
    m = jnp.full((B, H, shard, 1), rk.NEG_INF, jnp.float32)
    l = jnp.zeros((B, H, shard, 1), jnp.float32)
    acc = jnp.zeros((B, H, shard, D), jnp.float32)
    for step in range(N):
        src = jnp.mod(idx - step, N)
        m, l, acc = rk.ring_step_ref(
            qt, kv[0], kv[1], m, l, acc,
            q_offset=(idx * shard).astype(jnp.int32),
            k_offset=(src * shard).astype(jnp.int32),
            kv_len=jnp.int32(shard), scale=scale, causal=True,
        )
        if step < N - 1:
            kv = lax.ppermute(kv, name, perm)
    return (acc / jnp.maximum(l, 1e-30)).transpose(0, 2, 1, 3).astype(q.dtype)

ks = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(ks[0], (B, S, H, D))
k = jax.random.normal(ks[1], (B, S, H, D))
v = jax.random.normal(ks[2], (B, S, H, D))

def jit_of(fn):
    return jax.jit(_compat.shard_map(
        fn, mesh=mesh, in_specs=(spec, spec, spec), out_specs=spec))

with mesh:
    j_fused, j_raw = jit_of(fused), jit_of(raw)
    import numpy as np
    np.testing.assert_allclose(                     # same math before timing
        np.asarray(j_fused(q, k, v)), np.asarray(j_raw(q, k, v)),
        atol=1e-5, rtol=1e-5)
    # interleaved chunks so machine drift hits both sides alike; median ratio
    chunk, nchunks = max(3, reps // 5), 5
    gc.collect(); gc.disable()
    try:
        ftimes, rtimes = [], []
        for _ in range(nchunks):
            t0 = time.perf_counter()
            for _ in range(chunk):
                out = j_fused(q, k, v)
            jax.block_until_ready(out)
            ftimes.append((time.perf_counter() - t0) / chunk * 1e6)
            t0 = time.perf_counter()
            for _ in range(chunk):
                out = j_raw(q, k, v)
            jax.block_until_ready(out)
            rtimes.append((time.perf_counter() - t0) / chunk * 1e6)
    finally:
        gc.enable()
ratios = sorted(f / r for f, r in zip(ftimes, rtimes))
tax = ratios[len(ratios) // 2]
raw_us = sorted(rtimes)[len(rtimes) // 2]
rows = [{"devices": N, "msg_elems": S, "op": "ring_attention",
         "series": "ring", "raw_us": raw_us, "iface_us": raw_us * tax}]
print("RESULT " + json.dumps(rows))
"""


def ring_series(reps: int) -> list[dict]:
    env = {
        **os.environ,
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        "PYTHONPATH": str(ROOT / "src"),
    }
    proc = subprocess.run(
        [sys.executable, "-c", RING_CHILD, str(reps)],
        capture_output=True, text=True, env=env, timeout=1800, cwd=str(ROOT),
    )
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-3000:])
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError("no RESULT line")


def run(devices: int, msg_lens: list[int], reps: int) -> list[dict]:
    env = {
        **os.environ,
        "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
        "PYTHONPATH": str(ROOT / "src"),
    }
    proc = subprocess.run(
        [sys.executable, "-c", CHILD, json.dumps(msg_lens), str(reps)],
        capture_output=True, text=True, env=env, timeout=1800, cwd=str(ROOT),
    )
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-3000:])
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError("no RESULT line")


def geomean(xs):
    import math

    return math.exp(sum(math.log(max(x, 1e-9)) for x in xs) / len(xs))


def io_series(reps: int, quick: bool) -> list[dict]:
    """Checkpoint I/O bandwidth + async-overlap measurements (main process —
    file I/O needs no virtual devices)."""

    import tempfile
    import time

    import jax
    import jax.numpy as jnp

    sys.path.insert(0, str(ROOT / "src"))  # when PYTHONPATH was not exported

    from repro.checkpoint import CheckpointManager
    from repro.core import tool

    sizes = [1 << 18, 1 << 20] if quick else [1 << 18, 1 << 20, 1 << 22]
    reps = max(2, min(reps, 5))
    x = jnp.ones((512, 512), jnp.float32)
    step_fn = jax.jit(lambda a: a @ a.T / 512.0 + 1.0)
    jax.block_until_ready(step_fn(x))

    rows = []
    for n in sizes:
        # two dtype buckets (f32 + bf16) → two I/O requests per save
        state = {
            "w32": jnp.arange(n, dtype=jnp.float32),
            "w16": jnp.ones((n // 2,), jnp.bfloat16),
        }
        jax.block_until_ready(state)
        nbytes = 4 * n + 2 * (n // 2)

        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=2, async_save=False, verify=False)
            mgr.save(0, state)  # warm path/allocators
            c0 = tool.pvar_read().get("io_manifest_commit", 0)
            t0 = time.perf_counter()
            for r in range(reps):
                mgr.save(r + 1, state)
            sync_s = (time.perf_counter() - t0) / reps
            commits = (tool.pvar_read().get("io_manifest_commit", 0) - c0) / reps

        # calibrate a compute span comparable to one save
        t0 = time.perf_counter()
        jax.block_until_ready(step_fn(x))
        step_s = max(time.perf_counter() - t0, 1e-5)
        k = max(1, int(sync_s / step_s))

        def compute():
            y = x
            for _ in range(k):
                y = step_fn(y)
            jax.block_until_ready(y)

        # serial: blocking save then compute; overlapped: async save + the
        # same compute while the I/O requests run, then join
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=2, async_save=False, verify=False)
            mgr.save(0, state)
            t0 = time.perf_counter()
            for r in range(reps):
                mgr.save(r + 1, state)
                compute()
            serial_s = (time.perf_counter() - t0) / reps
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=2, async_save=True, verify=False)
            mgr.save(0, state)
            mgr.wait()
            issue_us = []
            t0 = time.perf_counter()
            for r in range(reps):
                # join the previous save outside the issue timer: save()'s
                # internal wait would otherwise charge residual I/O from the
                # last iteration to this iteration's "issue latency"
                mgr.wait()
                t1 = time.perf_counter()
                mgr.save(r + 1, state)
                issue_us.append((time.perf_counter() - t1) * 1e6)
                compute()
            mgr.wait()
            overlap_s = (time.perf_counter() - t0) / reps

        rows.append(
            {
                "series": "io",
                "state_mb": nbytes / 2**20,
                "sync_save_ms": sync_s * 1e3,
                "write_MBps": nbytes / 2**20 / sync_s,
                "issue_us": sum(issue_us) / len(issue_us),
                "serial_ms": serial_s * 1e3,
                "overlapped_ms": overlap_s * 1e3,
                "overlap_ratio": overlap_s / serial_s,
                "manifest_commits_per_save": commits,
            }
        )
        print(f"io: state={nbytes / 2**20:.1f}MB done")
    return rows


def serving_series(reps: int) -> list[dict]:
    """Continuous-batching scheduler tax: a full `engine.step()` — admission
    check, block-growth accounting, persistent decode re-fire, sampling,
    retirement bookkeeping — against the raw loop body it wraps (the same
    compiled decode executable fired directly, sampled and materialized).
    The claim: the scheduler adds <= 10% per step (main process, one
    device — the decode step itself is the unit under test)."""

    import gc
    import time

    import numpy as np

    sys.path.insert(0, str(ROOT / "src"))  # when PYTHONPATH was not exported

    import jax

    from repro.configs.base import ModelConfig, ParallelConfig
    from repro.launch.mesh import make_host_communicator
    from repro.runtime.engine import Engine, EngineConfig
    from repro.runtime.server import Server, ServerConfig

    chunk, nchunks = max(10, reps // 3), 8
    # a realistically-sized decode step (a few ms): the scheduler's per-step
    # cost is constant, so a toy-model step would overstate the tax by an
    # order of magnitude against any real serving workload
    cfg = ModelConfig(
        name="bench-engine", family="dense", num_layers=4, d_model=256,
        num_heads=8, num_kv_heads=4, head_dim=32, d_ff=1024, vocab_size=2048,
        dtype="float32",
    )
    # budget deep enough that no row retires inside the measurement window
    scfg = ServerConfig(
        max_batch=4, max_new_tokens=nchunks * chunk + 16, temperature=0.0
    )
    srv = Server(cfg, ParallelConfig(), scfg, make_host_communicator())
    rng = np.random.default_rng(0)

    # two engines over the same server (shared compiles): one driven by the
    # scheduler, one donating its state to the raw loop — so the engine and
    # raw chunks can be INTERLEAVED and machine drift hits both alike
    def fresh_engine():
        e = Engine(srv, EngineConfig(prompt_bucket=8, block_tokens=4))
        for _ in range(scfg.max_batch):
            e.submit(rng.integers(1, 128, size=(8,), dtype=np.int32))
        for _ in range(5):
            e.step()                                 # admit + warm compiles
        return e

    eng = fresh_engine()
    raw = fresh_engine()
    cache, tok = raw.cache, raw.tok
    decode = raw._decode_req
    key = jax.random.PRNGKey(0)

    # interleaved chunk pairs with GC parked: each pair times the engine
    # loop and the raw loop back-to-back in the same load window, so machine
    # drift cancels inside the pair; the tax is the trimmed mean of the pair
    # ratios (extremes are windows where one side ate a scheduler quantum —
    # the claim is about work, not jitter), reported at the median raw time
    gc.collect()
    gc.disable()
    try:
        etimes, rtimes = [], []
        for _ in range(nchunks):
            t0 = time.perf_counter()
            for _ in range(chunk):
                eng.step()
            etimes.append((time.perf_counter() - t0) / chunk * 1e6)
            with srv.mesh:
                t0 = time.perf_counter()
                for _ in range(chunk):
                    logits, cache = decode(srv.params, cache, tok)
                    t = srv._sample(logits, key)
                    tok = t[:, None]
                    np.asarray(t)
                rtimes.append((time.perf_counter() - t0) / chunk * 1e6)
    finally:
        gc.enable()
    ratios = sorted(e / r for e, r in zip(etimes, rtimes))
    inner = ratios[2:-2] if len(ratios) >= 6 else ratios
    tax = sum(inner) / len(inner)
    raw_us = sorted(rtimes)[len(rtimes) // 2]
    engine_us = raw_us * tax

    return [{
        "devices": 1, "msg_elems": 0, "op": "engine_step", "series": "serving",
        "raw_us": raw_us, "iface_us": engine_us,
    }]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--reps", type=int, default=30)
    args = ap.parse_args(argv)

    device_counts = [1, 2, 4, 8]
    msg_lens = [2 ** n for n in range(1, 18, 4 if args.quick else 2)]
    if args.quick:
        device_counts = [1, 8]

    all_rows = []
    for d in device_counts:
        all_rows += run(d, msg_lens, args.reps)
        print(f"devices={d}: done")
    io_rows = io_series(args.reps, args.quick)
    # fresh subprocess: the scheduler-tax measurement is Python-loop bound
    # and the checkpoint series leaves worker threads behind that would
    # bleed GIL time into it asymmetrically
    proc = subprocess.run(
        [sys.executable, "-c",
         "import json, sys\n"
         "from benchmarks.interface_overhead import serving_series\n"
         "print('RESULT ' + json.dumps(serving_series(int(sys.argv[1]))))",
         str(args.reps)],
        capture_output=True, text=True, timeout=1800, cwd=str(ROOT),
        env={**os.environ, "PYTHONPATH": str(ROOT / "src")},
    )
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-3000:])
    serving_rows = next(
        json.loads(line[len("RESULT "):])
        for line in proc.stdout.splitlines() if line.startswith("RESULT ")
    )
    all_rows += serving_rows
    ring_rows = ring_series(args.reps)
    all_rows += ring_rows

    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "interface_overhead.json").write_text(json.dumps(all_rows, indent=1))
    (OUT / "io_overhead.json").write_text(json.dumps(io_rows, indent=1))

    # paper-style summary: geometric mean over the op set per (devices, len)
    lines = ["| devices | msg elems | raw µs (geo) | interface µs (geo) | ratio |",
             "|---|---|---|---|---|"]
    worst = 0.0
    for d in device_counts:
        for n in msg_lens:
            rows = [r for r in all_rows if r["devices"] == d
                    and r["msg_elems"] == n and "series" not in r]
            g_raw = geomean([r["raw_us"] for r in rows])
            g_ifc = geomean([r["iface_us"] for r in rows])
            ratio = g_ifc / g_raw
            worst = max(worst, ratio)
            lines.append(f"| {d} | {n} | {g_raw:.1f} | {g_ifc:.1f} | {ratio:.3f} |")
    # persistent-vs-per-call series (MPI 4.0 persistent collectives):
    # per-call pays setup every call; persistent amortizes it into *_init
    plines = ["", "| devices | msg elems | per-call µs (geo) | init µs (geo) | "
              "persistent µs (geo) | amortization |",
              "|---|---|---|---|---|---|"]
    worst_persist = 0.0
    for d in device_counts:
        for n in msg_lens:
            prows = [r for r in all_rows
                     if r["devices"] == d and r["msg_elems"] == n and "persist_us" in r]
            if not prows:
                continue
            g_pc = geomean([r["percall_us"] for r in prows])
            g_init = geomean([r["init_us"] for r in prows])
            g_p = geomean([r["persist_us"] for r in prows])
            ratio = g_p / g_pc
            worst_persist = max(worst_persist, ratio)
            plines.append(
                f"| {d} | {n} | {g_pc:.1f} | {g_init:.1f} | {g_p:.1f} | {ratio:.4f} |"
            )
    # RMA series: window ops vs their raw lowering + epoch cost
    rlines = ["", "| devices | msg elems | op | raw µs | window µs | ratio |",
              "|---|---|---|---|---|---|"]
    worst_rma = 0.0
    for d in device_counts:
        for n in msg_lens:
            for r in all_rows:
                if (r["devices"] != d or r["msg_elems"] != n
                        or r.get("series") != "rma"):
                    continue
                ratio = r["iface_us"] / max(r["raw_us"], 1e-9)
                worst_rma = max(worst_rma, ratio)
                rlines.append(
                    f"| {d} | {n} | {r['op']} | {r['raw_us']:.1f} | "
                    f"{r['iface_us']:.1f} | {ratio:.3f} |"
                )
    # neighborhood series: interface tax vs the raw halo permutes, and the
    # sparse-vs-dense claim (neighbor exchange vs world alltoall at equal
    # per-neighbor payload)
    nlines = ["", "| devices | msg elems | op | raw µs | neighbor µs | ratio |",
              "|---|---|---|---|---|---|"]
    neigh_ratios = []
    for d in device_counts:
        for n in msg_lens:
            for r in all_rows:
                if (r["devices"] != d or r["msg_elems"] != n
                        or r.get("series") != "neighbor"):
                    continue
                ratio = r["iface_us"] / max(r["raw_us"], 1e-9)
                if r["op"] == "neighbor_allgather":
                    neigh_ratios.append(ratio)
                nlines.append(
                    f"| {d} | {n} | {r['op']} | {r['raw_us']:.1f} | "
                    f"{r['iface_us']:.1f} | {ratio:.3f} |"
                )
    # I/O series: checkpoint bandwidth + async overlap (single manifest
    # commit per save — the sync-point count is part of the claim)
    iolines = ["", "| state MB | sync save ms | MB/s | issue µs | serial ms | "
               "overlapped ms | overlap | commits/save |",
               "|---|---|---|---|---|---|---|---|"]
    worst_overlap = 0.0
    worst_commits = 0.0
    for r in io_rows:
        worst_overlap = max(worst_overlap, r["overlap_ratio"])
        worst_commits = max(worst_commits, r["manifest_commits_per_save"])
        iolines.append(
            f"| {r['state_mb']:.1f} | {r['sync_save_ms']:.1f} | "
            f"{r['write_MBps']:.0f} | {r['issue_us']:.0f} | "
            f"{r['serial_ms']:.1f} | {r['overlapped_ms']:.1f} | "
            f"{r['overlap_ratio']:.3f} | {r['manifest_commits_per_save']:.1f} |"
        )
    # serving series: continuous-batching scheduler tax over the raw
    # persistent-decode loop body it wraps
    slines = ["", "| op | raw step µs | engine step µs | scheduler tax |",
              "|---|---|---|---|"]
    serving_ratio = 0.0
    for r in serving_rows:
        ratio = r["iface_us"] / max(r["raw_us"], 1e-9)
        serving_ratio = max(serving_ratio, ratio)
        slines.append(f"| {r['op']} | {r['raw_us']:.1f} | {r['iface_us']:.1f} | "
                      f"{ratio:.3f} |")
    # ring-attention series: the fused futures-scheduled ring vs the raw
    # hand-written ppermute schedule (same math, same collectives)
    glines = ["", "| devices | seq | raw ring µs | fused ring µs | ring tax |",
              "|---|---|---|---|---|"]
    ring_tax = 0.0
    for r in ring_rows:
        ratio = r["iface_us"] / max(r["raw_us"], 1e-9)
        ring_tax = max(ring_tax, ratio)
        glines.append(
            f"| {r['devices']} | {r['msg_elems']} | {r['raw_us']:.1f} | "
            f"{r['iface_us']:.1f} | {ratio:.3f} |"
        )
    table = "\n".join(lines + plines + rlines + nlines + iolines + slines + glines)
    (OUT / "interface_overhead.md").write_text(table + "\n")
    print(table)
    print(f"worst geomean ratio: {worst:.3f} (paper claim: ~1.0, 'no recognizable disparity')")
    print(f"worst persistent/per-call ratio: {worst_persist:.4f} "
          "(claim: <= 1.0 — setup cost amortized by *_init + Start)")
    print(f"worst RMA/raw ratio: {worst_rma:.3f} "
          "(window epoch + masking tax over the bare collective)")
    if neigh_ratios:
        print(f"neighbor_allgather/raw-halo geomean ratio: "
              f"{geomean(neigh_ratios):.3f} "
              "(ch. 8 interface tax over hand-written halo permutes)")
    print(f"worst async/serial checkpoint ratio: {worst_overlap:.3f} "
          "(claim: < 1.0 — I/O requests overlap compute; "
          f"manifest commits per save: {worst_commits:.1f}, claim: exactly 1)")
    print(f"continuous-batching scheduler tax: {serving_ratio:.3f} "
          "(claim: <= 1.10 — engine.step() over the raw decode loop body)")
    print(f"ring attention tax: {ring_tax:.3f} "
          "(claim: <= 1.05 — fused futures-scheduled ring over the raw "
          "hand-written ppermute schedule)")
    ok = (worst_persist <= 1.0 and worst_commits == 1.0
          and serving_ratio <= 1.10 and ring_tax <= 1.05)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
