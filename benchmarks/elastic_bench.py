"""Elastic recovery cost: what one injected rank failure costs the job.

Two gated series (8 virtual devices, deterministic eviction schedule):

* ``elastic_recovery_steps`` — steps replayed per failure, i.e. the distance
  from the eviction back to the last *committed* manifest.  With
  ``checkpoint_every=2`` and the eviction one step past a save this is
  exactly 1 — any regression means the commit point or the restore-step
  bookkeeping drifted;
* ``elastic_rebuild_ratio`` — wall cost of the whole shrink path (revoke →
  ``Group.difference`` → fabric rebuild → restore → recompile) over a mean
  clean step.  Compile-dominated at smoke scale (the recompile IS most of
  it), so the gate gives it the same wide band as the other AOT-compile
  ratios.

    PYTHONPATH=src python -m benchmarks.elastic_bench [--quick]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
OUT = ROOT / "artifacts" / "bench"

CHILD = r"""
import json, statistics, tempfile, time
from repro.configs.base import ModelConfig, ParallelConfig
from repro.core import tool
from repro.core.communicator import Communicator
from repro.core.session import Session
from repro.runtime.faults import FaultInjector
from repro.runtime.trainer import Trainer, TrainerConfig

STEPS, EVICT_AT = 12, 7
cfg = ModelConfig(name="tiny", family="dense", num_layers=1, d_model=32,
                  num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                  vocab_size=64)
tcfg = TrainerConfig(steps=STEPS, lr=1e-3,
                     checkpoint_dir=tempfile.mkdtemp(prefix="elastic_bench_"),
                     checkpoint_every=2, log_every=1, seed=7)
world = Session.init().group("repro://world")
comm = Communicator.from_group(world, tag="repro://train", shape=(4, 2),
                               axis_names=("data", "model"))
inj = FaultInjector().evict_rank(EVICT_AT, 2)
t = Trainer(cfg, ParallelConfig(), tcfg, comm, seq_len=32, global_batch=12,
            injector=inj)

rebuild_wall = []
orig_shrink = t._shrink
def timed_shrink(evt):
    t0 = time.perf_counter()
    out = orig_shrink(evt)
    rebuild_wall.append(time.perf_counter() - t0)
    return out
t._shrink = timed_shrink

res = t.run()
assert res["final_step"] == STEPS and res["evictions"] == 1, res
recovery_steps = tool.pvar_read()["elastic:recovery_steps"]

# mean clean step: pre-eviction steady state (skip the warm-up step)
clean = [m["duration_s"] for m in res["metrics"] if 1 < m["step"] < EVICT_AT]
mean_clean = statistics.mean(clean)
print("RESULT " + json.dumps({
    "recovery_steps": recovery_steps,
    "rebuild_wall_s": rebuild_wall[0],
    "mean_clean_step_s": mean_clean,
    "rebuild_ratio": rebuild_wall[0] / max(mean_clean, 1e-9),
    "epoch": res["epoch"], "world_size": res["world_size"],
}))
"""


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="accepted for job-list symmetry")
    ap.parse_args(argv)

    env = {
        **os.environ,
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        "PYTHONPATH": str(ROOT / "src"),
    }
    proc = subprocess.run(
        [sys.executable, "-c", CHILD],
        capture_output=True, text=True, env=env, timeout=1800, cwd=str(ROOT),
    )
    if proc.returncode != 0:
        print(f"elastic_bench FAILED\n{proc.stderr[-2000:]}")
        return 1
    row = None
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT "):
            row = json.loads(line[len("RESULT "):])
    if row is None:
        print("elastic_bench produced no RESULT line")
        return 1
    print(
        f"eviction cost: {row['recovery_steps']} step(s) replayed, "
        f"shrink-rebuild-restore {row['rebuild_wall_s']*1e3:.0f} ms "
        f"({row['rebuild_ratio']:.1f}x a clean {row['mean_clean_step_s']*1e3:.0f} ms step)"
    )
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "elastic_bench.json").write_text(json.dumps(row, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
